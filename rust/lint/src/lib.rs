//! `hck-lint`: a zero-dependency static-analysis pass over the
//! workspace's own `.rs` sources.
//!
//! The analyzer is deliberately line/token-level — no `syn`, no parser
//! crate, consistent with the workspace's no-external-deps policy. A
//! small lexer blanks comments and literals out of each line (handling
//! strings, raw strings, char literals vs. lifetimes, and nested block
//! comments) so the token scans below never fire on text inside a
//! string or a comment, and a brace-depth tracker marks `#[cfg(test)]`
//! regions so test-only code is exempt from the serving-path rules.
//!
//! Rules (ids are stable; see [`RULES`]):
//!
//! * `safety-comment` — every `unsafe` keyword (block, fn, impl) must
//!   carry a `// SAFETY:` comment (or a `/// # Safety` doc section) on
//!   the same or contiguous preceding lines.
//! * `serving-no-panic` — serving-path modules (`coordinator/`,
//!   `shard/`, `model/mod.rs`, `model/persist.rs`, `infer.rs`) must not
//!   call `unwrap()` / `expect()` / the `panic!` family outside
//!   `#[cfg(test)]`; errors propagate as typed `PredictError` /
//!   `Error` values. `assert!`/`debug_assert!` are deliberately out of
//!   scope: they state invariants, and the worker pool converts any
//!   assertion failure into a typed shard error.
//! * `ordering-comment` — every atomic `Ordering::*` use must be
//!   justified by an `// ORDERING:` comment on the same line or the
//!   contiguous lines above the statement.
//! * `span-registry` — span names passed to `obs::span` / `span_req` /
//!   `span_with` / `record_span_between` must appear in the
//!   `pub const SPANS` table of `obs/registry.rs`, and every table
//!   entry must have at least one call site. Files under `obs/` (the
//!   tracer implementation) are exempt from the use scan.
//! * `thread-spawn` — `std::thread::spawn` / `thread::Builder` are
//!   confined to `util/parallel.rs`, `shard/worker.rs`,
//!   `shard/remote.rs`, `shard/balance.rs`, and `coordinator/`;
//!   everything else goes through the pool.
//! * `bad-allow` — the escape hatch itself is linted: an allow must
//!   name a known rule and carry a non-empty reason.
//!
//! Escape hatch: `// hck-lint: allow(<rule>): <reason>` on the
//! offending line, or on a comment-only line directly above it. The
//! reason is mandatory; a reasonless or unknown-rule allow is itself a
//! finding (`bad-allow`) and suppresses nothing.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// `(rule id, one-line description)` for every rule, in report order.
pub const RULES: &[(&str, &str)] = &[
    (
        "safety-comment",
        "every `unsafe` block/fn/impl carries a `// SAFETY:` (or `/// # Safety`) justification",
    ),
    (
        "serving-no-panic",
        "no unwrap()/expect()/panic!-family in serving-path modules outside #[cfg(test)]",
    ),
    (
        "ordering-comment",
        "every atomic Ordering::* use carries an `// ORDERING:` justification comment",
    ),
    (
        "span-registry",
        "span names used via obs::span*/record_span_between match the obs/registry.rs table",
    ),
    (
        "thread-spawn",
        "no thread::spawn/thread::Builder outside util/parallel.rs, shard/worker.rs, \
         shard/remote.rs, shard/balance.rs, coordinator/",
    ),
    (
        "bad-allow",
        "every `hck-lint: allow(<rule>)` escape names a known rule and carries a `: reason`",
    ),
];

/// One lint violation at a file:line.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Path as given on the command line (root-joined), for clickable output.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule id from [`RULES`].
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Result of a lint run.
pub struct Report {
    /// Sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

// ---------------------------------------------------------------------------
// Lexer: per-line code/comment separation
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    LineComment,
    Block(u32),
    Str,
    RawStr(u32),
}

/// One source line, lexed: `code` has comments removed and all literal
/// contents blanked to spaces (delimiters kept where cheap); `comment`
/// is the concatenated comment text of the line.
struct LexedLine {
    code: String,
    comment: String,
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn lex_lines(src: &str) -> Vec<LexedLine> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            lines.push(LexedLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        let next = if i + 1 < n { chars[i + 1] } else { '\0' };
        match mode {
            Mode::Code => {
                if c == '/' && next == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == '*' {
                    mode = Mode::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if c == 'r' {
                    // Raw string opener r"..." / r#"..."# (also br#"..."#).
                    // The `r` must not continue an identifier; raw
                    // identifiers (r#name) have no quote after the hashes
                    // and fall through to plain code.
                    let prev_ok = if i == 0 {
                        true
                    } else {
                        let p = chars[i - 1];
                        !is_ident_char(p) || (p == 'b' && (i < 2 || !is_ident_char(chars[i - 2])))
                    };
                    let mut j = i + 1;
                    let mut hashes = 0u32;
                    while j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if prev_ok && j < n && chars[j] == '"' {
                        code.push(' ');
                        mode = Mode::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push('r');
                        i += 1;
                    }
                } else if c == '\'' {
                    if next == '\\' {
                        // Escaped char literal: '\n', '\\', '\'', '\u{...}'.
                        code.push('\'');
                        i += 2; // opening quote + backslash
                        if i < n {
                            i += 1; // the escape selector (n, \, ', u, ...)
                        }
                        while i < n && chars[i] != '\'' && chars[i] != '\n' {
                            i += 1; // e.g. the {8} of '\u{8}'
                        }
                        if i < n && chars[i] == '\'' {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        let after = if i + 2 < n { chars[i + 2] } else { '\0' };
                        if next != '\0' && next != '\'' && next != '\n' && after == '\'' {
                            // Simple char literal 'x' — blanked so '{' / '"'
                            // payloads can't confuse depth/string tracking.
                            code.push('\'');
                            code.push(' ');
                            code.push('\'');
                            i += 3;
                        } else {
                            // Lifetime or loop label.
                            code.push('\'');
                            i += 1;
                        }
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::Block(depth) => {
                if c == '*' && next == '/' {
                    mode = if depth <= 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && next == '*' {
                    mode = Mode::Block(depth + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    code.push(' ');
                    // A backslash-newline continuation must not swallow the
                    // newline — line accounting depends on it.
                    if next == '\n' {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut k = 0u32;
                    while j < n && k < hashes && chars[j] == '#' {
                        k += 1;
                        j += 1;
                    }
                    if k == hashes {
                        code.push(' ');
                        mode = Mode::Code;
                        i = j;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(LexedLine { code, comment });
    }
    lines
}

// ---------------------------------------------------------------------------
// Per-file context
// ---------------------------------------------------------------------------

struct Allow {
    rule: String,
    has_reason: bool,
}

struct SourceFile {
    /// Root-joined path for display.
    display: String,
    /// Path relative to its scan root, '/'-separated (rule scoping).
    rel: String,
    raw: Vec<String>,
    code: Vec<String>,
    comment: Vec<String>,
    in_test: Vec<bool>,
    allows: Vec<Vec<Allow>>,
}

fn parse_allows(comment: &str) -> Vec<Allow> {
    // A directive must start the comment (after doc markers and
    // whitespace): prose that merely mentions the syntax is not an
    // escape. One directive per comment line.
    let t = comment
        .trim_start_matches(|c: char| c == '/' || c == '!' || c == '*' || c.is_whitespace());
    let Some(rest) = t.strip_prefix("hck-lint:") else {
        return Vec::new();
    };
    let rest = rest.trim_start();
    let parsed = rest.strip_prefix("allow(").and_then(|r| {
        let close = r.find(')')?;
        let rule = r[..close].trim().to_string();
        let tail = r[close + 1..].trim_start();
        let has_reason = match tail.strip_prefix(':') {
            Some(reason) => !reason.trim().is_empty(),
            None => false,
        };
        Some(Allow { rule, has_reason })
    });
    match parsed {
        Some(a) => vec![a],
        // `hck-lint:` followed by anything else is a malformed directive.
        None => vec![Allow { rule: String::new(), has_reason: false }],
    }
}

fn load_file(root: &Path, path: &Path) -> Result<SourceFile, String> {
    let src = fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    let rel = path
        .strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/");
    let lexed = lex_lines(&src);
    let mut raw: Vec<String> = src.lines().map(|l| l.to_string()).collect();
    // Keep raw/lexed lengths in lockstep (lex_lines drops a trailing
    // fully-empty line the same way `str::lines` does, but be defensive).
    while raw.len() < lexed.len() {
        raw.push(String::new());
    }

    // #[cfg(test)] region tracking: after the attribute, the next `{`
    // opens a test region that ends when depth returns below it. A `;`
    // before any `{` cancels (attribute on a braceless item).
    let mut in_test = Vec::with_capacity(lexed.len());
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut test_open_depth: Option<i64> = None;
    for line in &lexed {
        let mut line_test = test_open_depth.is_some();
        if line.code.contains("#[cfg(test)]") {
            pending = true;
            line_test = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending && test_open_depth.is_none() {
                        test_open_depth = Some(depth);
                        pending = false;
                    }
                    if test_open_depth.is_some() {
                        line_test = true;
                    }
                }
                '}' => {
                    if test_open_depth == Some(depth) {
                        test_open_depth = None;
                    }
                    depth -= 1;
                }
                ';' => {
                    if pending && test_open_depth.is_none() {
                        pending = false;
                    }
                }
                _ => {}
            }
        }
        in_test.push(line_test);
    }

    let allows: Vec<Vec<Allow>> = lexed.iter().map(|l| parse_allows(&l.comment)).collect();
    let (code, comment): (Vec<String>, Vec<String>) =
        lexed.into_iter().map(|l| (l.code, l.comment)).unzip();
    Ok(SourceFile {
        display: path.display().to_string(),
        rel,
        raw,
        code,
        comment,
        in_test,
        allows,
    })
}

// ---------------------------------------------------------------------------
// Token search helpers
// ---------------------------------------------------------------------------

/// First occurrence of `token` in `code` with identifier-boundary checks
/// on whichever ends of the token are identifier characters.
fn find_token(code: &str, token: &str) -> Option<usize> {
    let head_ident = token.chars().next().map(is_ident_char).unwrap_or(false);
    let tail_ident = token.chars().last().map(is_ident_char).unwrap_or(false);
    for (pos, _) in code.match_indices(token) {
        if head_ident {
            if let Some(prev) = code[..pos].chars().last() {
                if is_ident_char(prev) {
                    continue;
                }
            }
        }
        if tail_ident {
            if let Some(nextc) = code[pos + token.len()..].chars().next() {
                if is_ident_char(nextc) {
                    continue;
                }
            }
        }
        return Some(pos);
    }
    None
}

fn known_rule(rule: &str) -> bool {
    RULES.iter().any(|(id, _)| *id == rule)
}

/// Whether a valid allow for `rule` covers line `idx` (0-based): on the
/// line itself, or on contiguous comment-only lines directly above.
fn is_allowed(f: &SourceFile, idx: usize, rule: &str) -> bool {
    let hit = |allows: &[Allow]| {
        allows.iter().any(|a| a.rule == rule && a.has_reason && known_rule(&a.rule))
    };
    if hit(&f.allows[idx]) {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let code_blank = f.code[k].trim().is_empty();
        let has_comment = !f.comment[k].trim().is_empty();
        if code_blank && has_comment {
            if hit(&f.allows[k]) {
                return true;
            }
            continue;
        }
        break;
    }
    false
}

/// Whether a justification comment containing `needle1` (or `needle2`,
/// if non-empty) covers line `idx`: same line, or the contiguous
/// comment/attribute lines above — crossing continuation lines of the
/// same multi-line statement, stopping at the previous statement or
/// block boundary, a blank line, or after 40 lines. When `group_tokens`
/// is non-empty, earlier `;`-terminated statements that themselves
/// contain one of those tokens are crossed too, so one comment can
/// cover a contiguous run of atomic operations.
fn comment_above(
    f: &SourceFile,
    idx: usize,
    needle1: &str,
    needle2: &str,
    group_tokens: &[&str],
) -> bool {
    let matches = |s: &str| s.contains(needle1) || (!needle2.is_empty() && s.contains(needle2));
    if matches(&f.comment[idx]) {
        return true;
    }
    let mut k = idx;
    let mut steps = 0;
    let mut in_statement = true;
    while k > 0 && steps < 40 {
        k -= 1;
        steps += 1;
        if matches(&f.comment[k]) {
            return true;
        }
        let code_trim = f.code[k].trim();
        let has_comment = !f.comment[k].trim().is_empty();
        if code_trim.is_empty() {
            if has_comment {
                continue; // pure comment line: keep walking up
            }
            break; // blank line detaches the comment chain
        }
        if code_trim.starts_with("#[") || code_trim.starts_with("#![") {
            continue; // attributes sit between the comment and the item
        }
        if in_statement {
            let last = code_trim.chars().last().unwrap_or(' ');
            if last == ';' || last == '{' || last == '}' {
                if last == ';'
                    && group_tokens.iter().any(|t| find_token(code_trim, t).is_some())
                {
                    continue; // earlier statement of the same grouped run
                }
                in_statement = false;
                break; // previous statement/block boundary
            }
            continue; // continuation line of the same statement
        }
        break;
    }
    false
}

/// First plain string literal in `s`, if any (escape-aware enough for
/// span names, which contain none).
fn first_string_literal(s: &str) -> Option<String> {
    let bytes: Vec<char> = s.chars().collect();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == '"' {
            let mut out = String::new();
            i += 1;
            while i < bytes.len() && bytes[i] != '"' {
                if bytes[i] == '\\' && i + 1 < bytes.len() {
                    out.push(bytes[i + 1]);
                    i += 2;
                } else {
                    out.push(bytes[i]);
                    i += 1;
                }
            }
            return Some(out);
        }
        i += 1;
    }
    None
}

// ---------------------------------------------------------------------------
// Rule passes
// ---------------------------------------------------------------------------

fn is_serving_path(rel: &str) -> bool {
    rel.starts_with("coordinator/")
        || rel.starts_with("shard/")
        || rel == "model/mod.rs"
        || rel == "model/persist.rs"
        || rel == "infer.rs"
}

fn spawn_allowed_path(rel: &str) -> bool {
    // shard/remote.rs hosts the accept loop + per-connection handler
    // threads of the remote worker endpoint, and shard/balance.rs the
    // replica supervisor + detached hedge threads — network threads,
    // not compute, so they stay off the pool by design (like
    // serve_tcp's).
    rel == "util/parallel.rs"
        || rel == "shard/worker.rs"
        || rel == "shard/remote.rs"
        || rel == "shard/balance.rs"
        || rel.starts_with("coordinator/")
}

const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    ".unwrap_unchecked(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

const ORDERING_TOKENS: &[&str] = &[
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

const SPAWN_TOKENS: &[&str] = &["thread::spawn", "thread::Builder"];

const SPAN_TOKENS: &[&str] = &["span(", "span_req(", "span_with(", "record_span_between("];

struct SpanUse {
    name: Option<String>,
    file_idx: usize,
    line: usize, // 0-based
}

fn lint_file(f: &SourceFile, file_idx: usize, findings: &mut Vec<Finding>, uses: &mut Vec<SpanUse>) {
    let push = |findings: &mut Vec<Finding>, line: usize, rule: &'static str, msg: String| {
        findings.push(Finding { file: f.display.clone(), line: line + 1, rule, message: msg });
    };

    for idx in 0..f.code.len() {
        let code = &f.code[idx];

        // bad-allow: validate every escape on the line, test code included.
        for a in &f.allows[idx] {
            if a.rule.is_empty() {
                push(
                    findings,
                    idx,
                    "bad-allow",
                    "malformed `hck-lint:` directive \
                     (expected `hck-lint: allow(<rule>): <reason>`)"
                        .to_string(),
                );
            } else if !known_rule(&a.rule) {
                push(
                    findings,
                    idx,
                    "bad-allow",
                    format!(
                        "allow escape names unknown rule '{}' (known: {})",
                        a.rule,
                        RULES.iter().map(|(id, _)| *id).collect::<Vec<_>>().join(", ")
                    ),
                );
            } else if !a.has_reason {
                push(
                    findings,
                    idx,
                    "bad-allow",
                    format!(
                        "allow({}) requires a reason: `// hck-lint: allow({}): <why this is sound>`",
                        a.rule, a.rule
                    ),
                );
            }
        }

        if code.trim().is_empty() {
            continue;
        }

        // safety-comment: applies everywhere, tests included.
        if find_token(code, "unsafe").is_some()
            && !comment_above(f, idx, "SAFETY:", "# Safety", &[])
            && !is_allowed(f, idx, "safety-comment")
        {
            push(
                findings,
                idx,
                "safety-comment",
                "`unsafe` without a `// SAFETY:` comment stating the invariant relied on"
                    .to_string(),
            );
        }

        if f.in_test[idx] {
            continue;
        }

        // serving-no-panic
        if is_serving_path(&f.rel) {
            for tok in PANIC_TOKENS {
                if find_token(code, tok).is_some()
                    && !is_allowed(f, idx, "serving-no-panic")
                {
                    push(
                        findings,
                        idx,
                        "serving-no-panic",
                        format!(
                            "`{tok}` in a serving-path module; propagate a typed error instead"
                        ),
                    );
                }
            }
        }

        // ordering-comment
        if ORDERING_TOKENS.iter().any(|t| find_token(code, t).is_some())
            && !comment_above(f, idx, "ORDERING:", "", ORDERING_TOKENS)
            && !is_allowed(f, idx, "ordering-comment")
        {
            push(
                findings,
                idx,
                "ordering-comment",
                "atomic `Ordering::*` without an `// ORDERING:` justification comment"
                    .to_string(),
            );
        }

        // thread-spawn
        if !spawn_allowed_path(&f.rel) {
            for tok in SPAWN_TOKENS {
                if find_token(code, tok).is_some() && !is_allowed(f, idx, "thread-spawn") {
                    push(
                        findings,
                        idx,
                        "thread-spawn",
                        format!(
                            "`{tok}` outside util/parallel.rs, shard/worker.rs, \
                             shard/remote.rs, shard/balance.rs, coordinator/; use \
                             the worker pool"
                        ),
                    );
                }
            }
        }

        // span-registry: collect uses (the tracer's own files are exempt).
        if !f.rel.starts_with("obs/") {
            for tok in SPAN_TOKENS {
                if find_token(code, tok).is_none() {
                    continue;
                }
                // The span name is the first string literal at or after the
                // call token, on this or one of the next three lines.
                let mut name = None;
                for (off, line_idx) in (idx..f.raw.len().min(idx + 4)).enumerate() {
                    let hay = if off == 0 {
                        match f.raw[line_idx].find(tok) {
                            Some(p) => &f.raw[line_idx][p..],
                            None => f.raw[line_idx].as_str(),
                        }
                    } else {
                        f.raw[line_idx].as_str()
                    };
                    if let Some(lit) = first_string_literal(hay) {
                        name = Some(lit);
                        break;
                    }
                }
                uses.push(SpanUse { name, file_idx, line: idx });
            }
        }
    }
}

fn parse_registry(f: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut inside = false;
    for idx in 0..f.code.len() {
        if !inside {
            if f.code[idx].contains("pub const SPANS") {
                inside = true;
            }
            continue;
        }
        if f.code[idx].contains("];") {
            break;
        }
        if let Some(name) = first_string_literal(&f.raw[idx]) {
            out.push((name, idx));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for ent in rd {
        let ent = ent.map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
        entries.push(ent.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

fn load_roots(roots: &[PathBuf]) -> Result<Vec<SourceFile>, String> {
    let mut files = Vec::new();
    for root in roots {
        if root.is_file() {
            let parent = root.parent().unwrap_or(Path::new("")).to_path_buf();
            files.push(load_file(&parent, root)?);
            continue;
        }
        let mut paths = Vec::new();
        collect_rs(root, root, &mut paths)?;
        for p in &paths {
            files.push(load_file(root, p)?);
        }
    }
    Ok(files)
}

/// Lint every `.rs` file under `roots` (directories are walked
/// recursively; a file root is linted alone, relative to its parent).
pub fn lint_paths(roots: &[PathBuf]) -> Result<Report, String> {
    let files = load_roots(roots)?;
    let mut findings = Vec::new();
    let mut uses = Vec::new();
    for (file_idx, f) in files.iter().enumerate() {
        lint_file(f, file_idx, &mut findings, &mut uses);
    }

    // span-registry cross-file pass.
    let registry_file = files.iter().position(|f| f.rel.ends_with("obs/registry.rs"));
    let registry = registry_file.map(|i| parse_registry(&files[i])).unwrap_or_default();
    if registry_file.is_none() && !uses.is_empty() {
        let u = &uses[0];
        let f = &files[u.file_idx];
        findings.push(Finding {
            file: f.display.clone(),
            line: u.line + 1,
            rule: "span-registry",
            message: "span call sites found but no obs/registry.rs among the scanned roots"
                .to_string(),
        });
    } else {
        let mut used = vec![false; registry.len()];
        for u in &uses {
            let f = &files[u.file_idx];
            match &u.name {
                Some(name) => {
                    match registry.iter().position(|(n, _)| n == name) {
                        Some(i) => used[i] = true,
                        None => {
                            if !is_allowed(f, u.line, "span-registry") {
                                findings.push(Finding {
                                    file: f.display.clone(),
                                    line: u.line + 1,
                                    rule: "span-registry",
                                    message: format!(
                                        "span name \"{name}\" is not in obs/registry.rs \
                                         (add it to SPANS)"
                                    ),
                                });
                            }
                        }
                    }
                }
                None => {
                    if !is_allowed(f, u.line, "span-registry") {
                        findings.push(Finding {
                            file: f.display.clone(),
                            line: u.line + 1,
                            rule: "span-registry",
                            message: "span name must be a string literal on (or just after) \
                                      the call line so the registry check can see it"
                                .to_string(),
                        });
                    }
                }
            }
        }
        if let Some(reg_idx) = registry_file {
            let rf = &files[reg_idx];
            for (i, (name, line)) in registry.iter().enumerate() {
                if !used[i] && !is_allowed(rf, *line, "span-registry") {
                    findings.push(Finding {
                        file: rf.display.clone(),
                        line: line + 1,
                        rule: "span-registry",
                        message: format!(
                            "registered span \"{name}\" has no call site (remove the entry \
                             or instrument the code)"
                        ),
                    });
                }
            }
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Report { findings, files: files.len() })
}

/// Parse `obs/registry.rs` under `roots` and return the registered span
/// names in table order (the `--emit-spans` payload).
pub fn registry_names(roots: &[PathBuf]) -> Result<Vec<String>, String> {
    let files = load_roots(roots)?;
    let reg = files
        .iter()
        .find(|f| f.rel.ends_with("obs/registry.rs"))
        .ok_or_else(|| "no obs/registry.rs among the scanned roots".to_string())?;
    Ok(parse_registry(reg).into_iter().map(|(n, _)| n).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_blanks_strings_and_comments() {
        let lines = lex_lines("let x = \"unsafe // not code\"; // trailing unsafe\n");
        assert_eq!(lines.len(), 1);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].comment.contains("trailing unsafe"));
        assert!(lines[0].code.contains("let x ="));
    }

    #[test]
    fn lexer_handles_char_literals_and_lifetimes() {
        let lines = lex_lines("fn f<'a>(c: char) -> bool { c == '{' || c == '\\u{8}' }\n");
        let code = &lines[0].code;
        // The '{' payload is blanked; braces must stay balanced.
        let opens = code.matches('{').count();
        let closes = code.matches('}').count();
        assert_eq!(opens, closes, "unbalanced braces in {code:?}");
        assert!(code.contains("<'a>"), "lifetime mangled: {code:?}");
    }

    #[test]
    fn lexer_handles_raw_strings_and_escaped_braces() {
        let src = "let j = r#\"{\"k\": 1}\"#;\nlet s = format!(\"{{\\\"shard\\\":{id}}}\", id = 1);\n";
        let lines = lex_lines(src);
        assert_eq!(lines.len(), 2);
        assert!(!lines[0].code.contains('k'));
        // Only the interpolation braces of the format string survive; the
        // escaped JSON braces are string content and must be blanked.
        assert_eq!(lines[1].code.matches('{').count(), lines[1].code.matches('}').count());
    }

    #[test]
    fn cfg_test_regions_are_tracked() {
        let src = "fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn b() { y.unwrap(); }\n}\nfn c() {}\n";
        let f = write_temp("cfg_test.rs", src);
        let sf = load_file(f.parent().unwrap(), &f).unwrap();
        assert!(!sf.in_test[0]);
        assert!(sf.in_test[1] && sf.in_test[2] && sf.in_test[3] && sf.in_test[4]);
        assert!(!sf.in_test[5]);
    }

    #[test]
    fn allow_parsing_requires_reason() {
        let allows = parse_allows(" hck-lint: allow(safety-comment): fixture reason");
        assert_eq!(allows.len(), 1);
        assert!(allows[0].has_reason);
        let bad = parse_allows(" hck-lint: allow(safety-comment)");
        assert!(!bad[0].has_reason);
        let empty_reason = parse_allows(" hck-lint: allow(safety-comment):   ");
        assert!(!empty_reason[0].has_reason);
    }

    #[test]
    fn token_boundaries() {
        assert!(find_token("unsafe { }", "unsafe").is_some());
        assert!(find_token("#![forbid(unsafe_code)]", "unsafe").is_none());
        assert!(find_token("x.unwrap_or(0)", ".unwrap()").is_none());
        assert!(find_token("x.unwrap()", ".unwrap()").is_some());
        assert!(find_token("res.expect_err(", ".expect(").is_none());
    }

    fn write_temp(name: &str, contents: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("hck-lint-unit");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        fs::write(&path, contents).unwrap();
        path
    }
}
