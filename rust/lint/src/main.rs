//! CLI for `hck-lint`. Exit status: 0 clean, 1 findings, 2 usage/IO
//! error. Output is one `file:line: [rule] message` per finding plus a
//! one-line summary, so CI logs and editors can jump straight to the
//! violation.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
usage: hck-lint [--root DIR]... [--emit-spans] [--list-rules]

Lints every .rs file under the given roots (default: rust/src and
rust/lint/src relative to the current directory, falling back to src/
when run from inside rust/).

  --root DIR     add a directory (or single .rs file) to scan; repeatable
  --emit-spans   print the span names registered in obs/registry.rs, one
                 per line, and exit (input for check_trace.py --known-spans)
  --list-rules   print the rule table and exit
";

fn default_roots() -> Vec<PathBuf> {
    let mut roots = Vec::new();
    for cand in ["rust/src", "rust/lint/src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            roots.push(p);
        }
    }
    if roots.is_empty() {
        // Run from inside rust/: lint the package sources.
        for cand in ["src", "lint/src"] {
            let p = PathBuf::from(cand);
            if p.is_dir() {
                roots.push(p);
            }
        }
    }
    roots
}

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut emit_spans = false;
    let mut list_rules = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(dir) => roots.push(PathBuf::from(dir)),
                None => {
                    eprintln!("hck-lint: --root needs a directory argument\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--emit-spans" => emit_spans = true,
            "--list-rules" => list_rules = true,
            "--help" | "-h" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("hck-lint: unknown argument '{other}'\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }

    if list_rules {
        for (id, desc) in hck_lint::RULES {
            println!("{id:<18} {desc}");
        }
        return ExitCode::SUCCESS;
    }

    if roots.is_empty() {
        roots = default_roots();
    }
    if roots.is_empty() {
        eprintln!("hck-lint: no rust/src (or src) here; pass --root DIR\n{USAGE}");
        return ExitCode::from(2);
    }

    if emit_spans {
        return match hck_lint::registry_names(&roots) {
            Ok(names) => {
                for n in names {
                    println!("{n}");
                }
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("hck-lint: {e}");
                ExitCode::from(2)
            }
        };
    }

    match hck_lint::lint_paths(&roots) {
        Ok(report) => {
            for f in &report.findings {
                println!("{f}");
            }
            if report.findings.is_empty() {
                println!(
                    "hck-lint: clean — {} files scanned, {} rules",
                    report.files,
                    hck_lint::RULES.len()
                );
                ExitCode::SUCCESS
            } else {
                println!(
                    "hck-lint: {} finding(s) across {} scanned files",
                    report.findings.len(),
                    report.files
                );
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("hck-lint: {e}");
            ExitCode::from(2)
        }
    }
}
