//! Figure 7 — trade-off between n and r under a fixed memory budget nr:
//! performance of the hierarchical kernel as the training set is
//! progressively halved, for several r, against the exact-kernel
//! reference.
//!
//! Paper findings: performance improves consistently with r; for
//! covtype.binary growing n beats growing r, for YearPrediction the
//! reverse — the trade-off is data-set dependent.

#[path = "common.rs"]
mod common;

use common::*;
use hck::kernels::Gaussian;
use hck::learn::EngineSpec;
use hck::util::bench::Table;

fn main() {
    let lambda = 0.01;
    let ranks = [16usize, 32, 64, 128];
    let sizes = [500usize, 1000, 2000, 4000];
    for name in ["YearPredictionMSD", "covtype.binary"] {
        let (full_train, test) = dataset(name, *sizes.last().unwrap(), 800, 13);
        println!(
            "Figure 7 — n vs r trade-off on {name} (test n={}, λ={lambda})\n",
            test.n()
        );
        let mut table = Table::new(&[
            "n",
            "r=16",
            "r=32",
            "r=64",
            "r=128",
            "exact",
        ]);
        for &n in &sizes {
            let idx: Vec<usize> = (0..n).collect();
            let train = full_train.subset(&idx);
            let mut cells = vec![n.to_string()];
            for &r in &ranks {
                let res = best_over_sigma(
                    Gaussian::new(1.0),
                    &SIGMA_GRID_SMALL,
                    EngineSpec::Hierarchical { rank: r },
                    lambda,
                    3,
                    &train,
                    &test,
                );
                cells.push(match res {
                    Some((_, r)) => format!("{:.4}", r.metric),
                    None => "-".into(),
                });
            }
            // Exact reference (the paper used an EC2 cluster; we cap n).
            let exact = if n <= 2000 {
                best_over_sigma(
                    Gaussian::new(1.0),
                    &SIGMA_GRID_SMALL,
                    EngineSpec::Exact,
                    lambda,
                    3,
                    &train,
                    &test,
                )
                .map(|(_, r)| format!("{:.4}", r.metric))
                .unwrap_or_else(|| "-".into())
            } else {
                "-".into()
            };
            cells.push(exact);
            table.row(&cells);
        }
        table.print();
        println!();
    }
}
