//! Figure 8 — kernel PCA: alignment difference ‖U − ŨM‖_F/‖U‖_F between
//! each approximate kernel's 3-d embedding and the exact kernel's, vs r.
//!
//! Paper finding: the hierarchical kernel generally attains the smallest
//! alignment difference.

#[path = "common.rs"]
mod common;

use common::*;
use hck::approx::{FourierFeatures, NystromFeatures};
use hck::hkernel::{HConfig, HFactors};
use hck::kernels::{kernel_block, Gaussian};
use hck::learn::kpca::{
    alignment_difference, embed_from_kernel_matrix, kpca_embed_dense, kpca_embed_features,
    kpca_embed_hierarchical,
};
use hck::util::bench::Table;
use hck::util::rng::Rng;

fn main() {
    let dim = 3;
    let sets = [("cadata", 0.5), ("ijcnn1", 0.4), ("acoustic", 0.6), ("SUSY", 0.5)];
    for (name, sigma) in sets {
        let (train, _) = dataset(name, 600, 50, 9);
        let x = &train.x;
        let kind = Gaussian::new(sigma);
        let u_exact = kpca_embed_dense(kind, x, dim).expect("exact kpca");
        println!(
            "Figure 8 — kPCA alignment difference on {name} (n={}, σ={sigma}, dim={dim})\n",
            x.rows()
        );
        let mut table = Table::new(&["r", "nystrom", "fourier", "independent", "hierarchical"]);
        for r in [16usize, 32, 64, 128] {
            let mut rng = Rng::new(50 + r as u64);
            let nys = NystromFeatures::fit(kind, x, r, &mut rng)
                .and_then(|f| kpca_embed_features(&f.transform(x), dim))
                .and_then(|u| alignment_difference(&u_exact, &u))
                .map(|d| format!("{d:.4}"))
                .unwrap_or_else(|_| "-".into());
            let fou = FourierFeatures::sample(kind, x.cols(), r, &mut rng)
                .and_then(|f| kpca_embed_features(&f.transform(x), dim))
                .and_then(|u| alignment_difference(&u_exact, &u))
                .map(|d| format!("{d:.4}"))
                .unwrap_or_else(|_| "-".into());
            // Shared tree for independent + hierarchical (paper's setup:
            // the independent kernel flattens the same partitioning).
            let mut cfg = HConfig::new(kind, r).with_seed(70 + r as u64);
            cfg.n0 = r;
            let f = HFactors::build(x, cfg).expect("factors");
            let ind = {
                let kfull = kernel_block(kind, &f.rows_to_tree_order(x));
                let mut k = hck::linalg::Mat::zeros(x.rows(), x.rows());
                for &leaf in &f.tree.leaves() {
                    let nd = &f.tree.nodes[leaf];
                    for a in nd.lo..nd.hi {
                        for b in nd.lo..nd.hi {
                            k[(a, b)] = kfull[(a, b)];
                        }
                    }
                }
                embed_from_kernel_matrix(&k, dim)
                    .map(|u_tree| f.rows_from_tree_order(&u_tree))
                    .and_then(|u| alignment_difference(&u_exact, &u))
                    .map(|d| format!("{d:.4}"))
                    .unwrap_or_else(|_| "-".into())
            };
            let hier = kpca_embed_hierarchical(&f, dim, 60, &mut rng)
                .and_then(|u| alignment_difference(&u_exact, &u))
                .map(|d| format!("{d:.4}"))
                .unwrap_or_else(|_| "-".into());
            table.row(&[r.to_string(), nys, fou, ind, hier]);
        }
        table.print();
        println!();
    }
}
