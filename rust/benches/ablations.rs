//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. landmark-overlap policy (paper-faithful overlap vs disjoint
//!    sampling) — accuracy and factor stability;
//! 2. the λ′ stabilizer of §4.3 — sweep 0 → 1e-3;
//! 3. split rule used *inside* the hierarchical kernel (RP / PCA / k-d /
//!    k-means) — error and build time;
//! 4. tree arity via k-means k ∈ {2, 3, 4};
//! 5. covariance tapering (§1.2's third approach) as the base kernel of
//!    the exact engine vs the plain Gaussian — the sparse-baseline
//!    comparison the paper motivates and dismisses for dense settings.

#[path = "common.rs"]
mod common;

use common::*;
use hck::kernels::{tapered_gaussian, Gaussian};
use hck::learn::{EngineSpec, KrrModel, TrainConfig};
use hck::partition::SplitRule;
use hck::util::bench::{mean_std, Table};
use hck::util::timer::Timer;

fn main() {
    let (train, test) = dataset("cadata", 2000, 500, 3);
    let lambda = 0.01;
    let r = 64;
    let sigma = 0.5;

    // --- 1. landmark overlap policy ---
    println!("— ablation 1: landmark overlap (avoid_parent_landmarks) —");
    let mut table = Table::new(&["policy", "rel err (mean ± std, 8 seeds)", "factor ok"]);
    for (label, avoid) in [("overlap (paper)", false), ("disjoint", true)] {
        let errs: Vec<f64> = (0..8)
            .filter_map(|seed| {
                let mut hcfg =
                    hck::hkernel::HConfig::new(Gaussian::new(sigma), r).with_seed(seed);
                hcfg.n0 = r;
                hcfg.avoid_parent_landmarks = avoid;
                let f = hck::hkernel::HFactors::build(&train.x, hcfg).ok()?;
                let solver = hck::hkernel::HSolver::factor(&f, lambda).ok()?;
                let w = solver.solve_mat_original(&train.target_matrix());
                let pred = hck::hkernel::HPredictor::new(std::sync::Arc::new(f), &w);
                let p = pred.predict_batch(&test.x);
                Some(hck::learn::metrics::score(&test, &p).0)
            })
            .collect();
        let (m, s) = mean_std(&errs);
        table.row(&[label.into(), format!("{m:.4} ±{s:.4}"), format!("{}/8", errs.len())]);
    }
    table.print();
    println!("(push-through Woodbury keeps the overlap case exact despite singular G)\n");

    // --- 2. λ′ sweep ---
    println!("— ablation 2: λ′ stabilizer (§4.3) —");
    let mut table = Table::new(&["lambda'", "rel err"]);
    for lp in [0.0, 1e-10, 1e-8, 1e-5, 1e-3] {
        let mut cfg = TrainConfig::new(Gaussian::new(sigma), EngineSpec::Hierarchical { rank: r })
            .with_lambda(lambda)
            .with_seed(5);
        cfg.lambda_prime = lp;
        let err = KrrModel::fit_dataset(&cfg, &train)
            .map(|m| m.evaluate(&test))
            .unwrap_or(f64::NAN);
        table.row(&[format!("{lp:.0e}"), format!("{err:.4}")]);
    }
    table.print();
    println!("(flat — λ′ is a conditioning safeguard, not an accuracy knob)\n");

    // --- 3. split rule inside the hierarchical kernel ---
    println!("— ablation 3: split rule —");
    let mut table = Table::new(&["rule", "rel err", "total train (s)"]);
    for (label, rule) in [
        ("random projection", SplitRule::RandomProjection),
        ("pca", SplitRule::Pca { iters: 10 }),
        ("k-d", SplitRule::KdTree),
        ("k-means (k=2)", SplitRule::KMeans { k: 2, iters: 15 }),
    ] {
        let cfg = TrainConfig::new(Gaussian::new(sigma), EngineSpec::Hierarchical { rank: r })
            .with_lambda(lambda)
            .with_seed(5)
            .with_rule(rule);
        let t = Timer::start();
        let err = KrrModel::fit_dataset(&cfg, &train)
            .map(|m| m.evaluate(&test))
            .unwrap_or(f64::NAN);
        table.row(&[label.into(), format!("{err:.4}"), format!("{:.2}", t.secs())]);
    }
    table.print();
    println!();

    // --- 4. tree arity ---
    println!("— ablation 4: k-means arity —");
    let mut table = Table::new(&["k", "rel err", "tree depth"]);
    for k in [2usize, 3, 4] {
        let mut hcfg = hck::hkernel::HConfig::new(Gaussian::new(sigma), r).with_seed(5);
        hcfg.n0 = r;
        hcfg.rule = SplitRule::KMeans { k, iters: 15 };
        let err = (|| {
            let f = hck::hkernel::HFactors::build(&train.x, hcfg).ok()?;
            let depth = f.tree.depth();
            let solver = hck::hkernel::HSolver::factor(&f, lambda).ok()?;
            let w = solver.solve_mat_original(&train.target_matrix());
            let pred = hck::hkernel::HPredictor::new(std::sync::Arc::new(f), &w);
            let p = pred.predict_batch(&test.x);
            Some((hck::learn::metrics::score(&test, &p).0, depth))
        })();
        match err {
            Some((e, depth)) => table.row(&[k.to_string(), format!("{e:.4}"), depth.to_string()]),
            None => table.row(&[k.to_string(), "n/a".into(), "-".into()]),
        }
    }
    table.print();
    println!();

    // --- 5. covariance tapering baseline (§1.2) ---
    println!("— ablation 5: covariance tapering vs plain Gaussian (exact engine, n=1000) —");
    let idx: Vec<usize> = (0..1000).collect();
    let small = train.subset(&idx);
    let mut table = Table::new(&["kernel", "rel err", "zero fraction of K"]);
    {
        let cfg = TrainConfig::new(Gaussian::new(sigma), EngineSpec::Exact).with_lambda(lambda);
        let err = KrrModel::fit_dataset(&cfg, &small).map(|m| m.evaluate(&test)).unwrap();
        table.row(&["gaussian".into(), format!("{err:.4}"), "0.00".into()]);
    }
    for theta in [0.3, 0.6, 1.2] {
        let kind = tapered_gaussian(sigma, theta, small.d());
        let km = hck::kernels::kernel_block(kind, &small.x);
        let zeros =
            km.as_slice().iter().filter(|&&v| v == 0.0).count() as f64 / (1000.0 * 1000.0);
        let cfg = TrainConfig::new(kind, EngineSpec::Exact).with_lambda(lambda);
        let err = KrrModel::fit_dataset(&cfg, &small)
            .map(|m| m.evaluate(&test))
            .unwrap_or(f64::NAN);
        table.row(&[
            format!("tapered θ={theta}"),
            format!("{err:.4}"),
            format!("{zeros:.2}"),
        ]);
    }
    table.print();
    println!(
        "(paper §1.2: tapering trades accuracy for sparsity; the support must be\n \
         narrow for sparse algebra to pay off, which hurts prediction — as seen)"
    );
}
