//! Hot-path microbenchmarks — the profiling substrate for the
//! EXPERIMENTS.md §Perf iteration log.
//!
//! Covers: the packed BLAS-3 core (gemm GFLOP/s at square and
//! Nyström-rectangle factor sizes, syrk, and the **par_gemm
//! thread-scaling sweep**), native kernel-block evaluation (fused gemm
//! expansion vs naive), the PJRT AOT path per tile, Cholesky, the O(nr)
//! matvec and the per-query Algorithm-3 latency, coordinator batching
//! overhead, and the **parallel matvec thread-scaling sweep** — all
//! written to `BENCH_hotpath.json` (one row per (op, n, r, threads,
//! batch) with ns/op and GFLOP/s where meaningful) so every PR leaves a
//! machine-readable perf trajectory.
//!
//! `HCK_BENCH_QUICK=1` shrinks every size for the CI smoke job; the
//! default sizes include the n=50k thread-scaling sweep the perf gate
//! tracks.

#[path = "common.rs"]
mod common;

use common::*;
use hck::kernels::{kernel_cross, Gaussian, Laplace};
use hck::linalg::simd;
use hck::linalg::{gemm, par_gemm_with, syrk, Cholesky, Mat, Trans};
use hck::util::bench::{fmt_secs, gflops, Bench, BenchJson, Table};
use hck::util::json::Json;
use hck::util::parallel::{auto_threads, default_threads};
use hck::util::rng::Rng;

fn quick_mode() -> bool {
    std::env::var("HCK_BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

fn main() {
    let quick = quick_mode();
    let bench = if quick {
        Bench { warmup_iters: 1, measure_iters: 3, max_secs: 5.0 }
    } else {
        Bench { warmup_iters: 2, measure_iters: 7, max_secs: 20.0 }
    };
    let mut rng = Rng::new(1);
    let mut report = BenchJson::new("hotpath");
    if quick {
        println!("(HCK_BENCH_QUICK: reduced sizes)\n");
    }
    // The microkernel backend the packed core dispatched to. The forced
    // `*_scalar` rows below re-measure the same problems through the
    // scalar fallback so every report carries the SIMD-vs-scalar ratio
    // (and the perf gate can require the simd rows outright).
    let backend = simd::backend();
    println!(
        "SIMD backend: {} ({})\n",
        backend.name(),
        if std::env::var("HCK_SIMD").is_ok() {
            "forced via HCK_SIMD"
        } else {
            "runtime-detected"
        }
    );

    // ---- gemm (packed core): squares at factor sizes, dispatched
    // backend vs forced-scalar baseline ----
    println!("— gemm (C = A·B, square; packed core; backend {}) —", backend.name());
    let gemm_sizes: &[usize] = if quick { &[64, 128] } else { &[64, 128, 256, 512] };
    let mut table = Table::new(&["size", "scalar", backend.name(), "GFLOP/s", "vs scalar"]);
    for &n in gemm_sizes {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut c = Mat::zeros(n, n);
        let flops = 2.0 * (n as f64).powi(3);
        let prev = simd::force_backend(simd::Backend::Scalar).expect("scalar is always available");
        let m_scalar = bench.run("gemm_scalar", || {
            gemm(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
            c.as_slice()[0]
        });
        simd::force_backend(prev).expect("restore dispatched backend");
        let m = bench.run("gemm", || {
            gemm(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
            c.as_slice()[0]
        });
        table.row(&[
            format!("{n}"),
            fmt_secs(m_scalar.median()),
            fmt_secs(m.median()),
            format!("{:.2}", gflops(flops, m.median())),
            format!("{:.2}x", m_scalar.median() / m.median()),
        ]);
        report.row(vec![
            ("op", Json::Str("gemm_scalar".into())),
            ("n", Json::Num(n as f64)),
            ("backend", Json::Str("scalar".into())),
            ("ns_per_op", Json::Num(m_scalar.median() * 1e9)),
            ("gflops", Json::Num(gflops(flops, m_scalar.median()))),
        ]);
        report.row(vec![
            ("op", Json::Str("gemm".into())),
            ("n", Json::Num(n as f64)),
            ("backend", Json::Str(backend.name().into())),
            ("ns_per_op", Json::Num(m.median() * 1e9)),
            ("gflops", Json::Num(gflops(flops, m.median()))),
            ("speedup_vs_scalar", Json::Num(m_scalar.median() / m.median())),
        ]);
        // Presence marker for the CI perf gate: these rows exist only
        // when dispatch actually landed on a SIMD backend, so
        // `--require gemm_simd` fails if it silently regresses to
        // scalar on a machine that should have AVX2/NEON.
        if backend != simd::Backend::Scalar {
            report.row(vec![
                ("op", Json::Str("gemm_simd".into())),
                ("n", Json::Num(n as f64)),
                ("backend", Json::Str(backend.name().into())),
                ("ns_per_op", Json::Num(m.median() * 1e9)),
                ("gflops", Json::Num(gflops(flops, m.median()))),
            ]);
        }
    }
    table.print();

    // ---- gemm: Nyström / leaf-block rectangles (n×r)·(r×n) — the
    // shapes the O(nr²) chain actually multiplies ----
    println!("\n— gemm (C = A·B, n×r by r×n rectangles) —");
    let rect_shapes: &[(usize, usize)] =
        if quick { &[(512, 64)] } else { &[(1024, 512), (2048, 128), (4096, 64)] };
    let mut table = Table::new(&["n", "r", "median", "GFLOP/s"]);
    for &(n, r) in rect_shapes {
        let a = Mat::from_fn(n, r, |_, _| rng.normal());
        let b = Mat::from_fn(r, n, |_, _| rng.normal());
        let mut c = Mat::zeros(n, n);
        let m = bench.run("gemm_rect", || {
            gemm(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
            c.as_slice()[0]
        });
        let flops = 2.0 * (n * n * r) as f64;
        table.row(&[
            n.to_string(),
            r.to_string(),
            fmt_secs(m.median()),
            format!("{:.2}", gflops(flops, m.median())),
        ]);
        report.row(vec![
            ("op", Json::Str("gemm_rect".into())),
            ("n", Json::Num(n as f64)),
            ("r", Json::Num(r as f64)),
            ("backend", Json::Str(backend.name().into())),
            ("ns_per_op", Json::Num(m.median() * 1e9)),
            ("gflops", Json::Num(gflops(flops, m.median()))),
        ]);
    }
    table.print();

    // ---- syrk: the Gram/Schur updates (upper triangle + mirror),
    // dispatched backend vs forced-scalar baseline ----
    println!("\n— syrk (C = A·Aᵀ, A n×r; backend {}) —", backend.name());
    let syrk_shapes: &[(usize, usize)] =
        if quick { &[(256, 64)] } else { &[(512, 512), (1024, 256)] };
    let mut table = Table::new(&["n", "r", "scalar", backend.name(), "GFLOP/s", "vs scalar"]);
    for &(n, r) in syrk_shapes {
        let a = Mat::from_fn(n, r, |_, _| rng.normal());
        let mut c = Mat::zeros(n, n);
        // Triangle-only accumulation: ~n²·r madds instead of 2·n²·r.
        let flops = (n * n * r) as f64;
        let prev = simd::force_backend(simd::Backend::Scalar).expect("scalar is always available");
        let m_scalar = bench.run("syrk_scalar", || {
            syrk(1.0, &a, Trans::No, 0.0, &mut c);
            c.as_slice()[0]
        });
        simd::force_backend(prev).expect("restore dispatched backend");
        let m = bench.run("syrk", || {
            syrk(1.0, &a, Trans::No, 0.0, &mut c);
            c.as_slice()[0]
        });
        table.row(&[
            n.to_string(),
            r.to_string(),
            fmt_secs(m_scalar.median()),
            fmt_secs(m.median()),
            format!("{:.2}", gflops(flops, m.median())),
            format!("{:.2}x", m_scalar.median() / m.median()),
        ]);
        report.row(vec![
            ("op", Json::Str("syrk_scalar".into())),
            ("n", Json::Num(n as f64)),
            ("r", Json::Num(r as f64)),
            ("backend", Json::Str("scalar".into())),
            ("ns_per_op", Json::Num(m_scalar.median() * 1e9)),
            ("gflops", Json::Num(gflops(flops, m_scalar.median()))),
        ]);
        report.row(vec![
            ("op", Json::Str("syrk".into())),
            ("n", Json::Num(n as f64)),
            ("r", Json::Num(r as f64)),
            ("backend", Json::Str(backend.name().into())),
            ("ns_per_op", Json::Num(m.median() * 1e9)),
            ("gflops", Json::Num(gflops(flops, m.median()))),
            ("speedup_vs_scalar", Json::Num(m_scalar.median() / m.median())),
        ]);
    }
    table.print();

    // ---- par_gemm thread scaling on the largest square (the perf-gate
    // rows for the parallel BLAS layer; bitwise identical to gemm) ----
    let pg_n: usize = if quick { 256 } else { 1024 };
    let mut sweep_threads = vec![1usize, 2, 4];
    let dt = default_threads();
    if dt > 4 {
        sweep_threads.push(dt);
    }
    println!("\n— par_gemm thread scaling (n={pg_n}, threads: {sweep_threads:?}) —");
    {
        let a = Mat::from_fn(pg_n, pg_n, |_, _| rng.normal());
        let b = Mat::from_fn(pg_n, pg_n, |_, _| rng.normal());
        let mut c = Mat::zeros(pg_n, pg_n);
        let flops = 2.0 * (pg_n as f64).powi(3);
        let mut table = Table::new(&["threads", "median", "GFLOP/s", "speedup"]);
        let mut base_ns = f64::NAN;
        for &t in &sweep_threads {
            let m = bench.run("par_gemm", || {
                par_gemm_with(t, 1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
                c.as_slice()[0]
            });
            let ns = m.median() * 1e9;
            if t == 1 {
                base_ns = ns;
            }
            let speedup = base_ns / ns;
            table.row(&[
                t.to_string(),
                fmt_secs(m.median()),
                format!("{:.2}", gflops(flops, m.median())),
                format!("{speedup:.2}x"),
            ]);
            report.row(vec![
                ("op", Json::Str("par_gemm".into())),
                ("n", Json::Num(pg_n as f64)),
                ("threads", Json::Num(t as f64)),
                ("backend", Json::Str(backend.name().into())),
                ("ns_per_op", Json::Num(ns)),
                ("speedup_vs_1t", Json::Num(speedup)),
                ("gflops", Json::Num(gflops(flops, m.median()))),
            ]);
        }
        table.print();
    }

    // ---- kernel blocks: native ----
    let kb = if quick { 128 } else { 512 };
    println!("\n— kernel block K(X,Y), {kb}x{kb}, d=32 —");
    let x = Mat::from_fn(kb, 32, |_, _| rng.uniform(0.0, 1.0));
    let y = Mat::from_fn(kb, 32, |_, _| rng.uniform(0.0, 1.0));
    let mut table = Table::new(&["path", "median", "Melem/s"]);
    for (label, kind) in [
        ("native gaussian (gemm expansion)", Gaussian::new(0.5)),
        ("native laplace (blocked direct)", Laplace::new(0.5)),
    ] {
        let m = bench.run(label, || kernel_cross(kind, &x, &y));
        table.row(&[
            label.to_string(),
            fmt_secs(m.median()),
            format!("{:.1}", (kb * kb) as f64 / m.median() / 1e6),
        ]);
    }
    // PJRT path, if artifacts exist (stub build: never).
    if let Ok(engine) = hck::runtime::PjrtEngine::load_default() {
        for (label, kind) in [
            ("pjrt gaussian (AOT XLA f32)", Gaussian::new(0.5)),
            ("pjrt laplace (AOT XLA f32)", Laplace::new(0.5)),
        ] {
            let _ = engine.kernel_block(kind, &x, &y); // compile once
            let m = bench.run(label, || engine.kernel_block(kind, &x, &y).unwrap());
            table.row(&[
                label.to_string(),
                fmt_secs(m.median()),
                format!("{:.1}", (kb * kb) as f64 / m.median() / 1e6),
            ]);
        }
    } else {
        println!("(PJRT rows skipped: runtime unavailable)");
    }
    table.print();

    // ---- Cholesky at factor sizes ----
    println!("\n— Cholesky (SPD, kernel-matrix-like) —");
    let chol_sizes: &[usize] = if quick { &[128] } else { &[128, 256, 512] };
    let mut table = Table::new(&["n", "median"]);
    for &n in chol_sizes {
        let pts = Mat::from_fn(n, 8, |_, _| rng.uniform(0.0, 1.0));
        let mut k = kernel_cross(Gaussian::new(0.5), &pts, &pts);
        k.add_diag(0.1);
        let m = bench.run("chol", || Cholesky::new(&k).unwrap().logdet());
        table.row(&[n.to_string(), fmt_secs(m.median())]);
    }
    table.print();

    // ---- end-to-end hot paths ----
    let (eh_n, eh_r) = if quick { (2000, 32) } else { (8000usize, 64usize) };
    println!("\n— hierarchical hot paths (n={eh_n}, r={eh_r}) —");
    let (train, test) = dataset("SUSY", eh_n, 200, 3);
    let mut cfg = hck::hkernel::HConfig::new(Gaussian::new(0.5), eh_r).with_seed(4);
    cfg.n0 = eh_r;
    let f = std::sync::Arc::new(hck::hkernel::HFactors::build(&train.x, cfg).unwrap());
    let b: Vec<f64> = (0..eh_n).map(|i| (i as f64 * 0.01).sin()).collect();
    let mut table = Table::new(&["path", "median"]);
    let m = bench.run("matvec", || hck::hkernel::hmatvec(&f, &b));
    table.row(&["Algorithm 1 matvec (O(nr))".into(), fmt_secs(m.median())]);
    let m = bench.run("factor", || hck::hkernel::HSolver::factor(&f, 0.01).unwrap());
    table.row(&["solver factor (O(nr²))".into(), fmt_secs(m.median())]);
    report.row(vec![
        ("op", Json::Str("factor".into())),
        ("n", Json::Num(eh_n as f64)),
        ("r", Json::Num(eh_r as f64)),
        ("threads", Json::Num(auto_threads(eh_n) as f64)),
        ("ns_per_op", Json::Num(m.median() * 1e9)),
    ]);
    let solver = hck::hkernel::HSolver::factor(&f, 0.01).unwrap();
    let m = bench.run("solve", || solver.solve(&b));
    table.row(&["solver solve per rhs (O(nr))".into(), fmt_secs(m.median())]);
    report.row(vec![
        ("op", Json::Str("solve".into())),
        ("n", Json::Num(eh_n as f64)),
        ("r", Json::Num(eh_r as f64)),
        ("threads", Json::Num(auto_threads(eh_n) as f64)),
        ("ns_per_op", Json::Num(m.median() * 1e9)),
    ]);
    let w = Mat::from_vec(eh_n, 1, solver.solve(&f.to_tree_order(&b)));
    let wo = f.rows_from_tree_order(&w);
    let pred = hck::hkernel::HPredictor::new(f.clone(), &wo);
    let m = bench.run("oos", || {
        let mut acc = 0.0;
        for i in 0..test.n() {
            acc += pred.predict(test.x.row(i))[0];
        }
        acc
    });
    table.row(&[
        "Algorithm 3 per query".into(),
        fmt_secs(m.median() / test.n() as f64),
    ]);
    table.print();

    // ---- batched out-of-sample: per-query loop vs leaf-grouped gemms
    // vs the sharded scatter/gather path (the ISSUE-2 throughput rows).
    // The loop is the pre-refactor `predict_batch` behavior; the grouped
    // path shares leaf kernel blocks and path climbs across co-routed
    // queries; the sharded path fans the batch out over per-subtree
    // workers first. ----
    let batch_sizes: &[usize] = if quick { &[16, 64] } else { &[16, 64, 256, 1024] };
    let max_b = *batch_sizes.last().unwrap();
    let q_all = Mat::from_fn(max_b, test.d(), |i, j| test.x[(i % test.n(), j)]);
    let shard_depth = hck::shard::depth_for_shards(&f.tree, 4);
    let sharded = hck::shard::ShardedPredictor::new(&pred, shard_depth);
    println!(
        "\n— batched out-of-sample (n={eh_n}, r={eh_r}, {} shards at depth {shard_depth}) —",
        sharded.shards()
    );
    let mut table =
        Table::new(&["batch", "loop/q", "grouped/q", "sharded/q", "grouped speedup"]);
    for &bsz in batch_sizes {
        let q = q_all.row_range(0, bsz);
        let m_loop = bench.run("oos_loop", || {
            let mut acc = 0.0;
            for i in 0..q.rows() {
                acc += pred.predict(q.row(i))[0];
            }
            acc
        });
        let m_grp = bench.run("oos_grouped", || pred.predict_batch(&q));
        let m_shd = bench.run("oos_sharded", || {
            hck::coordinator::Predictor::predict_batch(&sharded, &q)
        });
        let per_q = |med: f64| med / bsz as f64;
        table.row(&[
            bsz.to_string(),
            fmt_secs(per_q(m_loop.median())),
            fmt_secs(per_q(m_grp.median())),
            fmt_secs(per_q(m_shd.median())),
            format!("{:.2}x", m_loop.median() / m_grp.median()),
        ]);
        for (op, med) in [
            ("oos_loop", m_loop.median()),
            ("oos_grouped", m_grp.median()),
            ("oos_sharded", m_shd.median()),
        ] {
            let mut row = vec![
                ("op", Json::Str(op.into())),
                ("n", Json::Num(eh_n as f64)),
                ("r", Json::Num(eh_r as f64)),
                ("batch", Json::Num(bsz as f64)),
                ("ns_per_query", Json::Num(per_q(med) * 1e9)),
            ];
            if op == "oos_sharded" {
                row.push(("shards", Json::Num(sharded.shards() as f64)));
            }
            report.row(row);
        }
    }
    table.print();

    // ---- remote fan-out over loopback shard-workers: the same shard
    // cut served by 1/2/4 worker processes-worth of sockets (in-process
    // listeners, real TCP + HCKW framing), so the wire + scatter/gather
    // overhead versus `oos_sharded` is a row in the telemetry. Shards
    // are distributed round-robin; `threads` carries the worker count
    // so the perf gate keys each configuration separately. ----
    let remote_batch = if quick { 64usize } else { 256 };
    let qr = q_all.row_range(0, remote_batch);
    println!("\n— remote fan-out (batch {remote_batch}, {} shards, loopback) —", sharded.shards());
    let mut table = Table::new(&["workers", "remote/q", "vs sharded"]);
    let m_shd_base = bench.run("oos_sharded_base", || {
        hck::coordinator::Predictor::predict_batch(&sharded, &qr)
    });
    for &nworkers in &[1usize, 2, 4] {
        let shards = hck::shard::split_predictor(&pred, shard_depth);
        let n_shards = shards.len();
        let mut groups: Vec<Vec<hck::shard::Shard>> = (0..nworkers).map(|_| Vec::new()).collect();
        for (i, s) in shards.into_iter().enumerate() {
            groups[i % nworkers].push(s);
        }
        let workers: Vec<hck::shard::RemoteWorker> = groups
            .into_iter()
            .filter(|g| !g.is_empty())
            .map(|g| hck::shard::RemoteWorker::serve("127.0.0.1:0", g, None).unwrap())
            .collect();
        let addrs: Vec<String> = workers.iter().map(|w| w.addr()).collect();
        let router = hck::shard::ShardRouter::new(
            &f.tree,
            &hck::shard::boundary_nodes(&f.tree, shard_depth),
        );
        let remote = hck::shard::RemoteShardedPredictor::connect(
            router,
            &addrs,
            std::time::Duration::from_millis(2000),
        )
        .unwrap();
        let m_rem = bench.run("oos_remote", || {
            hck::coordinator::Predictor::predict_batch(&remote, &qr)
        });
        table.row(&[
            nworkers.to_string(),
            fmt_secs(m_rem.median() / remote_batch as f64),
            format!("{:.2}x", m_rem.median() / m_shd_base.median()),
        ]);
        report.row(vec![
            ("op", Json::Str("oos_remote".into())),
            ("n", Json::Num(eh_n as f64)),
            ("r", Json::Num(eh_r as f64)),
            ("batch", Json::Num(remote_batch as f64)),
            ("threads", Json::Num(nworkers as f64)),
            ("shards", Json::Num(n_shards as f64)),
            ("ns_per_query", Json::Num(m_rem.median() * 1e9 / remote_batch as f64)),
        ]);
        drop(remote);
        for w in workers {
            w.shutdown();
        }
    }
    table.print();

    // ---- batched GP posterior variance (protocol v2's `variance`
    // capability): one column materialization + one blocked solve per
    // batch through the long-lived HVariance state. ----
    let var_batch = if quick { 16usize } else { 64 };
    let hv = hck::hkernel::HVariance::new(f.clone(), 0.01).expect("variance state");
    let qv = q_all.row_range(0, var_batch);
    let m_var = bench.run("oos_variance", || hv.variance_batch(&qv));
    println!(
        "\n— GP posterior variance (batch {var_batch}): {} per query —",
        fmt_secs(m_var.median() / var_batch as f64)
    );
    report.row(vec![
        ("op", Json::Str("oos_variance".into())),
        ("n", Json::Num(eh_n as f64)),
        ("r", Json::Num(eh_r as f64)),
        ("batch", Json::Num(var_batch as f64)),
        ("ns_per_query", Json::Num(m_var.median() * 1e9 / var_batch as f64)),
    ]);

    // ---- parallel matvec thread scaling (the perf gate rows) ----
    let scaling_cases: &[(usize, usize)] =
        if quick { &[(6000, 64)] } else { &[(8000, 64), (50000, 128)] };
    let mut threads_list = vec![1usize, 2, 4];
    let dt = default_threads();
    if dt > 4 {
        threads_list.push(dt);
    }
    println!("\n— parallel matvec scaling (threads: {threads_list:?}) —");
    for &(n, r) in scaling_cases {
        let (train, _) = dataset("SUSY", n, 10, 5);
        let mut cfg = hck::hkernel::HConfig::new(Gaussian::new(0.5), r).with_seed(6);
        cfg.n0 = r;
        let f = hck::hkernel::HFactors::build(&train.x, cfg).unwrap();
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.013).cos()).collect();
        let mut table = Table::new(&["n", "r", "threads", "median", "ns/op", "speedup"]);
        let mut base_ns = f64::NAN;
        for &t in &threads_list {
            let m = bench.run("pmv", || hck::hkernel::hmatvec_with_threads(&f, &b, t));
            let ns = m.median() * 1e9;
            if t == 1 {
                base_ns = ns;
            }
            let speedup = base_ns / ns;
            table.row(&[
                n.to_string(),
                r.to_string(),
                t.to_string(),
                fmt_secs(m.median()),
                format!("{ns:.0}"),
                format!("{speedup:.2}x"),
            ]);
            report.row(vec![
                ("op", Json::Str("matvec".into())),
                ("n", Json::Num(n as f64)),
                ("r", Json::Num(r as f64)),
                ("threads", Json::Num(t as f64)),
                ("ns_per_op", Json::Num(ns)),
                ("speedup_vs_1t", Json::Num(speedup)),
            ]);
        }
        table.print();
    }

    // ---- coordinator dispatch overhead ----
    println!("\n— coordinator batching overhead (trivial model) —");
    struct Noop;
    impl hck::coordinator::Predictor for Noop {
        fn predict(
            &self,
            req: &hck::infer::PredictRequest,
        ) -> hck::infer::InferResult<hck::infer::PredictResponse> {
            Ok(hck::infer::PredictResponse::of_mean(Mat::zeros(req.queries.rows(), 1)))
        }
        fn dim(&self) -> usize {
            4
        }
        fn outputs(&self) -> usize {
            1
        }
    }
    let svc = hck::coordinator::PredictionService::start(
        std::sync::Arc::new(Noop),
        hck::coordinator::BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_micros(200),
        },
    );
    let m = bench.run("roundtrip", || svc.predict(vec![0.0; 4]).unwrap());
    println!(
        "single-request queue→batch→respond round trip: {} (floor on serving latency)",
        fmt_secs(m.median())
    );

    // ---- span-sourced telemetry: per-level factor cost and coordinator
    // queue wait, read back through the obs tracer rather than wall-clock
    // wrappers, so the bench exercises the same instrumentation `--trace`
    // and `metrics_text` export in production. ----
    println!("\n— span-sourced telemetry (obs capture) —");
    hck::obs::enable_capture();
    let _ = hck::obs::drain_events(); // discard pre-capture buffered events
    let _ = hck::hkernel::HSolver::factor(&f, 0.01).unwrap();
    let mut levels: Vec<(f64, f64, f64)> = Vec::new(); // (level, nodes, dur_ns)
    for ev in hck::obs::drain_events() {
        if ev.name != "factor.level" {
            continue;
        }
        let args = Json::parse(ev.args.as_deref().unwrap_or("{}")).expect("span args are JSON");
        let level = args.get("level").and_then(Json::as_f64).unwrap_or(-1.0);
        let nodes = args.get("nodes").and_then(Json::as_f64).unwrap_or(0.0);
        levels.push((level, nodes, ev.dur_ns as f64));
    }
    levels.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut table = Table::new(&["level", "nodes", "time"]);
    for &(level, nodes, dur_ns) in &levels {
        table.row(&[format!("{level:.0}"), format!("{nodes:.0}"), fmt_secs(dur_ns / 1e9)]);
        report.row(vec![
            ("op", Json::Str("train_factor_per_level".into())),
            ("n", Json::Num(eh_n as f64)),
            ("r", Json::Num(eh_r as f64)),
            ("level", Json::Num(level)),
            ("nodes", Json::Num(nodes)),
            ("ns_per_op", Json::Num(dur_ns)),
        ]);
    }
    table.print();
    // Queue wait on the live coordinator — the time a request sits
    // between submit and batch execution, from the same `coord.queue_wait`
    // spans the serving trace carries.
    let wait_iters = if quick { 64usize } else { 256 };
    for _ in 0..wait_iters {
        svc.predict(vec![0.0; 4]).unwrap();
    }
    let waits: Vec<f64> = hck::obs::drain_events()
        .iter()
        .filter(|e| e.name == "coord.queue_wait")
        .map(|e| e.dur_ns as f64)
        .collect();
    hck::obs::disable();
    let mean_wait =
        if waits.is_empty() { 0.0 } else { waits.iter().sum::<f64>() / waits.len() as f64 };
    println!("coordinator queue wait: {mean_wait:.0} ns mean over {} requests", waits.len());
    report.row(vec![
        ("op", Json::Str("oos_queue_wait".into())),
        ("batch", Json::Num(1.0)),
        ("ns_per_query", Json::Num(mean_wait)),
    ]);

    // ---- HCKM artifact load (the serve-side cold start: read factors,
    // recompute Choleskys, rebuild the Algorithm-3 predictor) ----
    let (art_n, art_r) = if quick { (1000usize, 24usize) } else { (4000, 64) };
    println!("\n— HCKM artifact load (hierarchical, n={art_n}, r={art_r}) —");
    let (art_train, _) = dataset("cadata", art_n, 10, 9);
    let art_spec = hck::model::ModelSpec::krr(
        hck::learn::TrainConfig::new(
            Gaussian::new(0.5),
            hck::learn::EngineSpec::Hierarchical { rank: art_r },
        )
        .with_seed(7),
    );
    let art_model = hck::model::fit(&art_spec, &art_train).expect("fit artifact model");
    let art_path = std::env::temp_dir()
        .join(format!("hck_bench_artifact_{}.hckm", std::process::id()))
        .to_string_lossy()
        .into_owned();
    hck::model::Model::save(art_model.as_ref(), &art_path).expect("save artifact");
    let m = bench.run("artifact_load", || {
        hck::model::load_any(&art_path).expect("load artifact")
    });
    std::fs::remove_file(&art_path).ok();
    println!("load_any: {} per load", fmt_secs(m.median()));
    report.row(vec![
        ("op", Json::Str("artifact_load".into())),
        ("n", Json::Num(art_n as f64)),
        ("r", Json::Num(art_r as f64)),
        ("ns_per_op", Json::Num(m.median() * 1e9)),
    ]);

    // Cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the telemetry at the workspace root so CI picks it up at a
    // fixed path regardless of the invoking directory.
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match report.write(out_path) {
        Ok(()) => println!("\nwrote {out_path} ({} rows)", report.len()),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
