//! Hot-path microbenchmarks — the profiling substrate for the
//! EXPERIMENTS.md §Perf iteration log.
//!
//! Covers: the gemm microkernel (GFLOP/s at factor-relevant sizes),
//! native kernel-block evaluation (gemm expansion vs naive), the PJRT
//! AOT path per tile, Cholesky, the O(nr) matvec and the per-query
//! Algorithm-3 latency, and coordinator batching overhead.

#[path = "common.rs"]
mod common;

use common::*;
use hck::kernels::{kernel_cross, Gaussian, KernelKind, Laplace};
use hck::linalg::{gemm, Cholesky, Mat, Trans};
use hck::util::bench::{fmt_secs, Bench, Table};
use hck::util::rng::Rng;

fn main() {
    let bench = Bench { warmup_iters: 2, measure_iters: 7, max_secs: 20.0 };
    let mut rng = Rng::new(1);

    // ---- gemm ----
    println!("— gemm (C = A·B, square) —");
    let mut table = Table::new(&["size", "median", "GFLOP/s"]);
    for n in [64usize, 128, 256, 512] {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut c = Mat::zeros(n, n);
        let m = bench.run("gemm", || {
            gemm(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
            c.as_slice()[0]
        });
        let flops = 2.0 * (n as f64).powi(3);
        table.row(&[
            format!("{n}"),
            fmt_secs(m.median()),
            format!("{:.2}", flops / m.median() / 1e9),
        ]);
    }
    table.print();

    // ---- kernel blocks: native ----
    println!("\n— kernel block K(X,Y), 512x512, d=32 —");
    let x = Mat::from_fn(512, 32, |_, _| rng.uniform(0.0, 1.0));
    let y = Mat::from_fn(512, 32, |_, _| rng.uniform(0.0, 1.0));
    let mut table = Table::new(&["path", "median", "Melem/s"]);
    for (label, kind) in [
        ("native gaussian (gemm expansion)", Gaussian::new(0.5)),
        ("native laplace (blocked direct)", Laplace::new(0.5)),
    ] {
        let m = bench.run(label, || kernel_cross(kind, &x, &y));
        table.row(&[
            label.to_string(),
            fmt_secs(m.median()),
            format!("{:.1}", 512.0 * 512.0 / m.median() / 1e6),
        ]);
    }
    // PJRT path, if artifacts exist.
    if let Ok(engine) = hck::runtime::PjrtEngine::load_default() {
        for (label, kind) in [
            ("pjrt gaussian (AOT XLA f32)", Gaussian::new(0.5)),
            ("pjrt laplace (AOT XLA f32)", Laplace::new(0.5)),
        ] {
            let _ = engine.kernel_block(kind, &x, &y); // compile once
            let m = bench.run(label, || engine.kernel_block(kind, &x, &y).unwrap());
            table.row(&[
                label.to_string(),
                fmt_secs(m.median()),
                format!("{:.1}", 512.0 * 512.0 / m.median() / 1e6),
            ]);
        }
    } else {
        println!("(PJRT rows skipped: run `make artifacts`)");
    }
    table.print();

    // ---- Cholesky at factor sizes ----
    println!("\n— Cholesky (SPD, kernel-matrix-like) —");
    let mut table = Table::new(&["n", "median"]);
    for n in [128usize, 256, 512] {
        let pts = Mat::from_fn(n, 8, |_, _| rng.uniform(0.0, 1.0));
        let mut k = kernel_cross(Gaussian::new(0.5), &pts, &pts);
        k.add_diag(0.1);
        let m = bench.run("chol", || Cholesky::new(&k).unwrap().logdet());
        table.row(&[n.to_string(), fmt_secs(m.median())]);
    }
    table.print();

    // ---- end-to-end hot paths ----
    println!("\n— hierarchical hot paths (n=8000, r=64) —");
    let (train, test) = dataset("SUSY", 8000, 200, 3);
    let mut cfg = hck::hkernel::HConfig::new(Gaussian::new(0.5), 64).with_seed(4);
    cfg.n0 = 64;
    let f = std::sync::Arc::new(hck::hkernel::HFactors::build(&train.x, cfg).unwrap());
    let b: Vec<f64> = (0..8000).map(|i| (i as f64 * 0.01).sin()).collect();
    let mut table = Table::new(&["path", "median"]);
    let m = bench.run("matvec", || hck::hkernel::hmatvec(&f, &b));
    table.row(&["Algorithm 1 matvec (O(nr))".into(), fmt_secs(m.median())]);
    let m = bench.run("factor", || hck::hkernel::HSolver::factor(&f, 0.01).unwrap());
    table.row(&["solver factor (O(nr²))".into(), fmt_secs(m.median())]);
    let solver = hck::hkernel::HSolver::factor(&f, 0.01).unwrap();
    let m = bench.run("solve", || solver.solve(&b));
    table.row(&["solver solve per rhs (O(nr))".into(), fmt_secs(m.median())]);
    let w = Mat::from_vec(8000, 1, solver.solve(&f.to_tree_order(&b)));
    let wo = f.rows_from_tree_order(&w);
    let pred = hck::hkernel::HPredictor::new(f.clone(), &wo);
    let m = bench.run("oos", || {
        let mut acc = 0.0;
        for i in 0..test.n() {
            acc += pred.predict(test.x.row(i))[0];
        }
        acc
    });
    table.row(&[
        "Algorithm 3 per query".into(),
        fmt_secs(m.median() / test.n() as f64),
    ]);
    table.print();

    // ---- coordinator dispatch overhead ----
    println!("\n— coordinator batching overhead (trivial model) —");
    struct Noop;
    impl hck::coordinator::Predictor for Noop {
        fn predict_batch(&self, q: &Mat) -> Mat {
            Mat::zeros(q.rows(), 1)
        }
        fn dim(&self) -> usize {
            4
        }
        fn outputs(&self) -> usize {
            1
        }
    }
    let svc = hck::coordinator::PredictionService::start(
        std::sync::Arc::new(Noop),
        hck::coordinator::BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_micros(200),
        },
    );
    let m = bench.run("roundtrip", || svc.predict(vec![0.0; 4]).unwrap());
    println!(
        "single-request queue→batch→respond round trip: {} (floor on serving latency)",
        fmt_secs(m.median())
    );
    let _ = kind_guard();
}

fn kind_guard() -> KernelKind {
    Gaussian::new(1.0)
}
