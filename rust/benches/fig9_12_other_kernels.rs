//! Figures 9–12 — the Figures 5–6 comparison repeated with the Laplace
//! base kernel (Figs. 9–10) and the inverse multiquadric (Figs. 11–12;
//! no Fourier column — its spectral density is not tabulated, paper
//! §5.4).
//!
//! Paper finding: results are qualitatively the same as the Gaussian —
//! the (optimal) λ is large relative to kernel values, so base-kernel
//! smoothness matters little.

#[path = "common.rs"]
mod common;

use common::*;
use hck::kernels::{Imq, KernelKind, Laplace};
use hck::learn::EngineSpec;
use hck::util::bench::Table;

fn main() {
    let lambda = 0.01;
    let sets: &[(&str, usize, usize)] = &[
        ("cadata", 2000, 500),
        ("covtype.binary", 2500, 600),
        ("SUSY", 2500, 600),
        ("acoustic", 2000, 500),
    ];
    for (figs, base, with_fourier) in [
        ("Figures 9–10 (Laplace)", Laplace::new(1.0), true),
        ("Figures 11–12 (inverse multiquadric)", Imq::new(1.0), false),
    ] {
        println!("=== {figs}, λ={lambda} ===\n");
        for &(name, ntr, nte) in sets {
            let (train, test) = dataset(name, ntr, nte, 5);
            println!("--- {name} (n={}, task={:?}) ---", train.n(), train.task);
            let mut table = Table::new(&["engine", "r", "metric", "train (s)"]);
            for r in [32usize, 128] {
                let mut specs: Vec<EngineSpec> = vec![
                    EngineSpec::Nystrom { rank: r },
                    EngineSpec::Independent { n0: r },
                    EngineSpec::Hierarchical { rank: r },
                ];
                if with_fourier {
                    specs.insert(1, EngineSpec::Fourier { rank: r });
                }
                for engine in specs {
                    match best_over_sigma(
                        base_kind(base),
                        &SIGMA_GRID_SMALL,
                        engine,
                        lambda,
                        9,
                        &train,
                        &test,
                    ) {
                        Some((_, res)) => table.row(&[
                            engine.name().to_string(),
                            r.to_string(),
                            fmt_metric(res.metric, res.higher_is_better),
                            format!("{:.2}", res.train_secs),
                        ]),
                        None => table.row(&[
                            engine.name().to_string(),
                            r.to_string(),
                            "n/a".into(),
                            "-".into(),
                        ]),
                    }
                }
            }
            table.print();
            println!();
        }
    }
}

fn base_kind(k: KernelKind) -> KernelKind {
    k
}
