//! Section 4.5 cost analysis — empirical verification of the claimed
//! complexities: O(nr) matvec (Algorithm 1), O(nr²) factorization +
//! O(nr) solve (≡ Algorithm 2), ≈4nr memory, and the per-query
//! out-of-sample cost (Algorithm 3, eq. 23).
//!
//! Prints measured times with fitted scaling exponents: time ∝ n^a at
//! fixed r (expect a ≈ 1) and ∝ r^b at fixed n (expect b ≈ 2 for the
//! factorization).

#[path = "common.rs"]
mod common;

use common::*;
use hck::hkernel::{hmatvec, HConfig, HFactors, HPredictor, HSolver};
use hck::kernels::Gaussian;
use hck::linalg::Mat;
use hck::util::bench::{Bench, Table};
use hck::util::rng::Rng;
use std::sync::Arc;

fn build(n: usize, r: usize, seed: u64) -> Arc<HFactors> {
    let (train, _) = dataset("SUSY", n, 10, seed);
    let mut cfg = HConfig::new(Gaussian::new(0.5), r).with_seed(seed);
    cfg.n0 = r;
    Arc::new(HFactors::build(&train.x, cfg).expect("build"))
}

fn fit_exponent(xs: &[f64], ys: &[f64]) -> f64 {
    // Least-squares slope of log y on log x.
    let n = xs.len() as f64;
    let lx: Vec<f64> = xs.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|v| v.ln()).collect();
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(a, b)| (a - mx) * (b - my)).sum();
    let var: f64 = lx.iter().map(|a| (a - mx) * (a - mx)).sum();
    cov / var
}

fn main() {
    let bench = Bench { warmup_iters: 1, measure_iters: 3, max_secs: 60.0 };

    // ---- Scaling in n at fixed r ----
    println!("— scaling in n (r = 64) —");
    let mut table = Table::new(&["n", "matvec (ms)", "factor (ms)", "solve (ms)", "mem words/4nr"]);
    let ns = [2000usize, 4000, 8000, 16000];
    let mut t_mv = Vec::new();
    let mut t_fac = Vec::new();
    let mut t_sol = Vec::new();
    for &n in &ns {
        let f = build(n, 64, 1);
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
        let m_mv = bench.run("mv", || hmatvec(&f, &b));
        let solver = HSolver::factor(&f, 0.01).unwrap();
        let m_fac = bench.run("fac", || HSolver::factor(&f, 0.01).unwrap());
        let m_sol = bench.run("sol", || solver.solve(&b));
        let mem_ratio = f.memory_words() as f64 / (4.0 * (n * 64) as f64);
        t_mv.push(m_mv.median());
        t_fac.push(m_fac.median());
        t_sol.push(m_sol.median());
        table.row(&[
            n.to_string(),
            format!("{:.2}", m_mv.median() * 1e3),
            format!("{:.1}", m_fac.median() * 1e3),
            format!("{:.2}", m_sol.median() * 1e3),
            format!("{:.2}", mem_ratio),
        ]);
    }
    table.print();
    let nsf: Vec<f64> = ns.iter().map(|&v| v as f64).collect();
    println!(
        "fitted exponents in n: matvec {:.2} (expect ≈1), factor {:.2} (≈1), solve {:.2} (≈1)\n",
        fit_exponent(&nsf, &t_mv),
        fit_exponent(&nsf, &t_fac),
        fit_exponent(&nsf, &t_sol)
    );

    // ---- Scaling in r at fixed n ----
    println!("— scaling in r (n = 8192) —");
    let mut table = Table::new(&["r", "matvec (ms)", "factor (ms)", "oos (µs/query)"]);
    let rs = [32usize, 64, 128, 256];
    let mut fac_r = Vec::new();
    for &r in &rs {
        let f = build(8192, r, 2);
        let b: Vec<f64> = (0..8192).map(|i| (i as f64 * 0.01).cos()).collect();
        let m_mv = bench.run("mv", || hmatvec(&f, &b));
        let m_fac = bench.run("fac", || HSolver::factor(&f, 0.01).unwrap());
        fac_r.push(m_fac.median());
        // Algorithm 3 per-query latency.
        let w = Mat::from_vec(8192, 1, b.clone());
        let pred = HPredictor::new(f.clone(), &w);
        let mut rng = Rng::new(3);
        let queries: Vec<Vec<f64>> = (0..200)
            .map(|_| (0..f.x.cols()).map(|_| rng.uniform(0.0, 1.0)).collect())
            .collect();
        let m_oos = bench.run("oos", || {
            let mut acc = 0.0;
            for q in &queries {
                acc += pred.predict(q)[0];
            }
            acc
        });
        table.row(&[
            r.to_string(),
            format!("{:.2}", m_mv.median() * 1e3),
            format!("{:.1}", m_fac.median() * 1e3),
            format!("{:.1}", m_oos.median() * 1e6 / 200.0),
        ]);
    }
    table.print();
    let rsf: Vec<f64> = rs.iter().map(|&v| v as f64).collect();
    println!(
        "fitted exponent of factor time in r: {:.2} (expect ≈2 for O(nr²); note the\n\
         n/r leaf count shrinks as r grows, so the pure-r exponent reads below 2)",
        fit_exponent(&rsf, &fac_r)
    );
}
