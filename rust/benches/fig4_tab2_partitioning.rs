//! Figure 4 + Table 2 — partitioning approaches.
//!
//! Figure 4: error-vs-σ bands of the hierarchical kernel under random
//! projection vs PCA partitioning (means nearly identical; PCA slightly
//! tighter bands).
//!
//! Table 2: the *cost* of PCA — overhead of the dominant-singular-vector
//! computation relative to (a) the partitioning step and (b) total
//! training, per data set and r. Paper finding: overhead vs partitioning
//! easily exceeds 100% (thousands of % for mnist, the largest d).

#[path = "common.rs"]
mod common;

use common::*;
use hck::hkernel::{size_rule_from_rank, HConfig, HFactors, HSolver};
use hck::kernels::{Gaussian, NativeEvaluator};
use hck::learn::EngineSpec;
use hck::partition::{PartitionTree, SplitRule};
use hck::util::bench::{mean_std, Table};
use hck::util::rng::Rng;
use hck::util::timer::Timer;

fn main() {
    fig4();
    table2();
}

fn fig4() {
    let repeats = 8;
    let lambda = 0.01;
    let (train, test) = dataset("cadata", 2000, 500, 3);
    println!("Figure 4 — hierarchical error vs sigma: random projection vs PCA\n");
    for (label, rule) in [
        ("random-projection", SplitRule::RandomProjection),
        ("pca", SplitRule::Pca { iters: 10 }),
    ] {
        println!("--- {label}, r = 64 ---");
        let mut table = Table::new(&["sigma", "mean err", "std"]);
        for &sigma in SIGMA_GRID_WIDE.iter() {
            let errs: Vec<f64> = (0..repeats)
                .filter_map(|seed| {
                    let cfg = hck::learn::TrainConfig::new(
                        Gaussian::new(sigma),
                        EngineSpec::Hierarchical { rank: 64 },
                    )
                    .with_lambda(lambda)
                    .with_seed(seed)
                    .with_rule(rule);
                    hck::learn::KrrModel::fit_dataset(&cfg, &train)
                        .ok()
                        .map(|m| m.evaluate(&test))
                })
                .collect();
            let (mean, std) = mean_std(&errs);
            table.row(&[format!("{sigma}"), format!("{mean:.4}"), format!("{std:.4}")]);
        }
        table.print();
        println!();
    }
}

fn table2() {
    println!("Table 2 — PCA overhead vs partitioning and vs total training\n");
    let sets: &[(&str, usize)] = &[
        ("cadata", 2000),
        ("YearPredictionMSD", 2000),
        ("ijcnn1", 2000),
        ("covtype.binary", 2000),
        ("SUSY", 2000),
        ("mnist", 1500),
        ("acoustic", 2000),
        ("covtype", 2000),
    ];
    let mut table = Table::new(&["dataset", "r", "vs partitioning", "vs training"]);
    for &(name, n) in sets {
        let (train, _) = dataset(name, n, 50, 7);
        for j in [4u32, 3] {
            let (n0, r, _) = size_rule_from_rank(train.n(), train.n() >> j);
            // Time RP partitioning vs PCA partitioning.
            let time_rule = |rule: SplitRule| {
                let mut rng = Rng::new(11);
                let t = Timer::start();
                let tree = PartitionTree::build(&train.x, n0, rule, &mut rng);
                (t.secs(), tree)
            };
            let (t_rp, tree) = time_rule(SplitRule::RandomProjection);
            let (t_pca, _) = time_rule(SplitRule::Pca { iters: 10 });
            let overhead = (t_pca - t_rp).max(0.0);
            // Total training time with RP (instantiate + factor + solve).
            let t_total = {
                let mut cfg = HConfig::new(Gaussian::new(0.5), r).with_seed(11);
                cfg.n0 = n0;
                let mut rng = Rng::new(11);
                let t = Timer::start();
                let f = HFactors::build_on_tree(&train.x, cfg, tree, &mut rng, &NativeEvaluator)
                    .expect("build");
                let solver = HSolver::factor(&f, 0.01).expect("factor");
                let y: Vec<f64> = train.y.clone();
                let _ = solver.solve(&f.to_tree_order(&y));
                t.secs() + t_rp
            };
            table.row(&[
                name.to_string(),
                r.to_string(),
                format!("{:.1}%", 100.0 * overhead / t_rp.max(1e-9)),
                format!("{:.1}%", 100.0 * overhead / t_total.max(1e-9)),
            ]);
        }
    }
    table.print();
    println!("\n(paper: overhead vs partitioning often >100%, mnist extreme due to d=780)");
}
