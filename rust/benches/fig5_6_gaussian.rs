//! Figures 5–6 — the main comparison: performance vs r, training time and
//! memory for the four approximate kernels on all eight Table-1
//! analogues, Gaussian base kernel. σ is grid-searched per (engine, r)
//! with a fixed seed (the paper's protocol), λ = 0.01.
//!
//! Paper findings to reproduce:
//! - hierarchical almost always best performance-vs-r (except YearPred);
//! - Fourier fastest, then Nyström ≈ independent, hierarchical slowest;
//! - covtype (binary + multiclass): large gap between full-rank local
//!   kernels and low-rank ones.

#[path = "common.rs"]
mod common;

use common::*;
use hck::kernels::Gaussian;
use hck::util::bench::Table;

fn main() {
    let lambda = 0.01;
    let sets: &[(&str, usize, usize)] = &[
        ("cadata", 2000, 500),
        ("YearPredictionMSD", 2500, 600),
        ("ijcnn1", 2500, 600),
        ("covtype.binary", 2500, 600),
        ("SUSY", 3000, 700),
        ("mnist", 1500, 400),
        ("acoustic", 2500, 600),
        ("covtype", 2500, 600),
    ];
    let ranks = [32usize, 64, 128];
    println!("Figures 5–6 — performance / time / memory vs r (Gaussian kernel, λ={lambda})\n");
    for &(name, ntr, nte) in sets {
        let (train, test) = dataset(name, ntr, nte, 5);
        println!(
            "=== {name} (n={} d={} task={:?}) ===",
            train.n(),
            train.d(),
            train.task
        );
        let mut table =
            Table::new(&["engine", "r", "metric", "sigma*", "train (s)", "mem (norm r/pt)"]);
        let mut winners: Vec<(f64, bool, String)> = Vec::new();
        for &r in &ranks {
            for engine in engines(r) {
                let Some((sig, res)) = best_over_sigma(
                    Gaussian::new(1.0),
                    &SIGMA_GRID_SMALL,
                    engine,
                    lambda,
                    9,
                    &train,
                    &test,
                ) else {
                    continue;
                };
                // Normalized memory (words per training point) — the
                // paper's §5 model: r for low-rank/independent, ~4r for
                // hierarchical.
                let mem_per_pt = res.memory_words as f64 / train.n() as f64;
                table.row(&[
                    engine.name().to_string(),
                    r.to_string(),
                    fmt_metric(res.metric, res.higher_is_better),
                    format!("{sig}"),
                    format!("{:.2}", res.train_secs),
                    format!("{:.0}", mem_per_pt),
                ]);
                if r == ranks[ranks.len() - 1] {
                    winners.push((res.metric, res.higher_is_better, engine.name().to_string()));
                }
            }
        }
        table.print();
        // Who wins at the largest r?
        if let Some(best) = winners
            .iter()
            .max_by(|a, b| {
                let va = if a.1 { a.0 } else { -a.0 };
                let vb = if b.1 { b.0 } else { -b.0 };
                va.partial_cmp(&vb).unwrap()
            })
        {
            println!("best at r={}: {}\n", ranks[ranks.len() - 1], best.2);
        }
    }
}
