//! Shared helpers for the paper-reproduction benchmark suite.
//!
//! Every bench target regenerates one table/figure of the paper's
//! evaluation (see DESIGN.md §6 for the index) and prints the same
//! rows/series the paper plots. Sizes are scaled to this single-core
//! testbed (the paper used a 12-core POWER8 with up to 4M points);
//! the *shape* of the comparisons is what must hold.

#![allow(dead_code)]

use hck::data::{spec_by_name, synthetic, Dataset};
use hck::kernels::KernelKind;
use hck::learn::{EngineSpec, KrrModel, TrainConfig};
use hck::util::timer::Timer;

/// Scaled data sizes per benchmark tier. Override the scale with
/// HCK_BENCH_SCALE (1 = quick default, 2 = double, ...).
pub fn scale() -> f64 {
    std::env::var("HCK_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Generate (train, test) for a named Table-1 analogue at a scaled size.
///
/// When `HCK_BENCH_DATA=<dir-or-file>` is set and a LIBSVM file for
/// `name` is found there, the **real** data set is loaded (normalized,
/// deduplicated, deterministically subsampled to the requested sizes)
/// instead of the synthetic analogue — the paper's true benchmarks slot
/// into every figure/table target once the files are present. Falls back
/// to the synthetic generator when the variable is unset, the file is
/// missing, or it holds too few rows.
pub fn dataset(name: &str, n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    let s = scale();
    let nt = ((n_train as f64) * s) as usize;
    let ns = ((n_test as f64) * s) as usize;
    if let Ok(root) = std::env::var("HCK_BENCH_DATA") {
        let root = root.trim();
        if !root.is_empty() {
            match real_dataset(root, name, nt, ns, seed) {
                Some(pair) => return pair,
                None => eprintln!(
                    "(HCK_BENCH_DATA: no usable LIBSVM file for '{name}' under {root}; \
                     falling back to synthetic)"
                ),
            }
        }
    }
    let spec = spec_by_name(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
    synthetic::generate(spec, nt, ns, seed)
}

/// Resolve and load a real LIBSVM data set for `name`: `root` may be a
/// directory holding `<name>`, `<name>.libsvm` or `<name>.txt`, or the
/// file itself — accepted only when its stem matches `name`, so a
/// single-file HCK_BENCH_DATA never mislabels other data sets' rows.
/// Returns None (caller falls back to synthetic) when no file matches
/// or it has fewer than n_train + n_test usable rows.
fn real_dataset(
    root: &str,
    name: &str,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> Option<(Dataset, Dataset)> {
    let mut candidates = vec![
        format!("{root}/{name}"),
        format!("{root}/{name}.libsvm"),
        format!("{root}/{name}.txt"),
    ];
    let root_path = std::path::Path::new(root);
    if root_path.is_file()
        && root_path
            .file_stem()
            .map(|s| s.to_string_lossy().eq_ignore_ascii_case(name))
            .unwrap_or(false)
    {
        candidates.insert(0, root.to_string());
    }
    let path = candidates.into_iter().find(|p| std::path::Path::new(p).is_file())?;
    let mut ds = hck::data::libsvm::load(&path, name).ok()?;
    hck::data::preprocess::normalize_unit(&mut ds);
    hck::data::preprocess::dedup_conflicts(&mut ds);
    let need = n_train + n_test;
    if n_train == 0 || ds.n() < need {
        return None;
    }
    // Deterministic subsample: the same seed draws the same rows, so the
    // perf trajectory stays comparable across runs.
    let mut rng = hck::util::rng::Rng::new(seed ^ 0x5eed_da7a);
    let picked = rng.sample_indices(ds.n(), need);
    let train = ds.subset(&picked[..n_train]);
    let test = ds.subset(&picked[n_train..]);
    eprintln!(
        "(HCK_BENCH_DATA: using {path} for '{name}' — {} train / {} test of {} rows)",
        train.n(),
        test.n(),
        ds.n()
    );
    Some((train, test))
}

/// The four approximate kernels of Section 5, at comparable size r.
pub fn engines(r: usize) -> Vec<EngineSpec> {
    vec![
        EngineSpec::Nystrom { rank: r },
        EngineSpec::Fourier { rank: r },
        EngineSpec::Independent { n0: r },
        EngineSpec::Hierarchical { rank: r },
    ]
}

/// One trained-and-evaluated measurement.
pub struct RunResult {
    pub metric: f64,
    pub higher_is_better: bool,
    pub train_secs: f64,
    pub memory_words: usize,
}

/// Train one engine with fixed (σ, λ, seed) and evaluate.
pub fn run_once(
    kind: KernelKind,
    engine: EngineSpec,
    lambda: f64,
    seed: u64,
    train: &Dataset,
    test: &Dataset,
) -> Option<RunResult> {
    let cfg = TrainConfig::new(kind, engine).with_lambda(lambda).with_seed(seed);
    let t = Timer::start();
    let model = KrrModel::fit_dataset(&cfg, train).ok()?;
    let train_secs = t.secs();
    let pred = model.predict(&test.x);
    let (metric, hib) = hck::learn::metrics::score(test, &pred);
    Some(RunResult {
        metric,
        higher_is_better: hib,
        train_secs,
        memory_words: model.memory_words,
    })
}

/// Sweep σ over a grid with a fixed seed and return the best run
/// (the paper's protocol: grid search σ and λ, no repetitions).
pub fn best_over_sigma(
    base_kind: KernelKind,
    sigmas: &[f64],
    engine: EngineSpec,
    lambda: f64,
    seed: u64,
    train: &Dataset,
    test: &Dataset,
) -> Option<(f64, RunResult)> {
    let mut best: Option<(f64, RunResult)> = None;
    for &s in sigmas {
        let Some(r) = run_once(base_kind.with_sigma(s), engine, lambda, seed, train, test)
        else {
            continue;
        };
        let better = match &best {
            None => true,
            Some((_, b)) => {
                if r.higher_is_better {
                    r.metric > b.metric
                } else {
                    r.metric < b.metric
                }
            }
        };
        if better {
            best = Some((s, r));
        }
    }
    best
}

/// σ grids the sweeps use (log-spaced, spanning the paper's 0.01–100).
pub const SIGMA_GRID_WIDE: [f64; 8] = [0.02, 0.05, 0.15, 0.4, 1.0, 3.0, 10.0, 50.0];
pub const SIGMA_GRID_SMALL: [f64; 4] = [0.1, 0.3, 0.8, 2.0];

/// Format a metric with its direction.
pub fn fmt_metric(value: f64, higher_is_better: bool) -> String {
    if higher_is_better {
        format!("acc {value:.4}")
    } else {
        format!("err {value:.4}")
    }
}
