//! Figure 3 — effect of randomness: regression error vs σ on the cadata
//! analogue, mean ± std over repeated seeds, for all four approximate
//! kernels at r ∈ {32, 129}.
//!
//! Paper finding to reproduce: the hierarchical kernel has the most
//! stable error curve (narrowest band); Nyström varies at small σ, the
//! independent kernel degrades badly at large σ, Fourier is non-smooth.

#[path = "common.rs"]
mod common;

use common::*;
use hck::kernels::Gaussian;
use hck::util::bench::{mean_std, Table};

fn main() {
    let repeats = 10; // paper: 30
    let lambda = 0.01;
    let (train, test) = dataset("cadata", 2000, 500, 3);
    println!(
        "Figure 3 — error vs sigma under randomness (cadata-like, n={}, {} seeds, λ={lambda})\n",
        train.n(),
        repeats
    );
    for r in [32usize, 129] {
        println!("--- r = {r} ---");
        let mut table = Table::new(&["sigma", "nystrom", "fourier", "independent", "hierarchical"]);
        let mut band_width = vec![0.0f64; 4];
        for &sigma in SIGMA_GRID_WIDE.iter() {
            let mut cells = vec![format!("{sigma}")];
            for (ei, engine) in engines(r).into_iter().enumerate() {
                let errs: Vec<f64> = (0..repeats)
                    .filter_map(|seed| {
                        run_once(Gaussian::new(sigma), engine, lambda, seed, &train, &test)
                            .map(|r| r.metric)
                    })
                    .collect();
                let (mean, std) = mean_std(&errs);
                band_width[ei] += std;
                cells.push(format!("{mean:.4} ±{std:.4}"));
            }
            table.row(&cells);
        }
        table.print();
        let names = ["nystrom", "fourier", "independent", "hierarchical"];
        println!("\ncumulative std over the sweep (lower = more stable):");
        for (n, b) in names.iter().zip(band_width.iter()) {
            println!("  {n:<13} {b:.4}");
        }
        let hier_band = band_width[3];
        let min_other = band_width[..3].iter().cloned().fold(f64::INFINITY, f64::min);
        println!(
            "hierarchical band vs best other: {:.2}x {}\n",
            hier_band / min_other,
            if hier_band <= min_other * 1.15 { "(paper: most stable ✓)" } else { "" }
        );
    }
}
