//! Integration tests for the obs span tracer: the disabled fast path,
//! span nesting across pool threads, Chrome-trace export validity, and
//! request-id propagation.
//!
//! The tracer is process-global, so every test that toggles it holds
//! `SERIAL` — the cargo test harness runs test fns in parallel threads
//! within one process, and two tests flipping the enable flag would
//! otherwise see each other's events.

use hck::obs;
use hck::util::json::Json;
use std::sync::Mutex;
use std::time::Instant;

static SERIAL: Mutex<()> = Mutex::new(());

/// Grab the serial guard even if a prior test panicked while holding it.
fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn disabled_tracer_records_nothing() {
    let _g = serial();
    obs::disable();
    let _ = obs::drain_events();
    assert!(!obs::is_enabled());
    {
        let _outer = obs::span("outer", "test");
        let _inner = obs::span_with("inner", "test", || {
            panic!("args closure must not run when tracing is disabled")
        });
        let _req = obs::span_req("req", "test", 9);
    }
    let t = Instant::now();
    obs::record_span_between("between", "test", t, t, 1);
    assert!(obs::drain_events().is_empty());
}

#[test]
fn spans_nest_and_capture_across_threads() {
    let _g = serial();
    obs::disable();
    let _ = obs::drain_events();
    obs::enable_capture();

    {
        let _outer = obs::span("outer", "test");
        std::thread::sleep(std::time::Duration::from_millis(1));
        let _inner = obs::span("inner", "test");
    } // inner drops first, then outer

    // Worker threads record into their own rings; parallel_map drives
    // the shared pool the instrumented hot paths use.
    let outs = hck::util::parallel::parallel_map(4, &[1usize, 2, 3, 4, 5, 6, 7, 8], |&i| {
        let _sp = obs::span_with("worker", "test", || format!("{{\"item\":{i}}}"));
        std::thread::sleep(std::time::Duration::from_millis(1));
        i * 2
    });
    assert_eq!(outs, vec![2, 4, 6, 8, 10, 12, 14, 16]);

    obs::disable();
    let events = obs::drain_events();

    let outer = events.iter().find(|e| e.name == "outer").expect("outer span recorded");
    let inner = events.iter().find(|e| e.name == "inner").expect("inner span recorded");
    assert_eq!(outer.tid, inner.tid, "nested spans share a thread");
    assert!(outer.start_ns <= inner.start_ns, "outer opens before inner");
    assert!(
        outer.start_ns + outer.dur_ns >= inner.start_ns + inner.dur_ns,
        "outer closes after inner ({} + {} vs {} + {})",
        outer.start_ns,
        outer.dur_ns,
        inner.start_ns,
        inner.dur_ns
    );

    let workers: Vec<_> = events.iter().filter(|e| e.name == "worker").collect();
    assert_eq!(workers.len(), 8, "one span per pool item");
    for w in &workers {
        let args = Json::parse(w.args.as_deref().unwrap()).expect("worker args parse");
        assert!(args.get("item").and_then(Json::as_usize).is_some());
    }

    // drain_events sorts globally and leaves the rings empty.
    assert!(events.windows(2).all(|p| p[0].start_ns <= p[1].start_ns));
    assert!(obs::drain_events().is_empty());
}

#[test]
fn request_id_context_attaches_to_spans() {
    let _g = serial();
    obs::disable();
    let _ = obs::drain_events();
    obs::enable_capture();

    assert_eq!(obs::current_request_id(), 0);
    {
        let _rid = obs::with_request_id(42);
        assert_eq!(obs::current_request_id(), 42);
        let _sp = obs::span("scoped", "test");
        {
            let _rid2 = obs::with_request_id(43);
            assert_eq!(obs::current_request_id(), 43);
            let _sp2 = obs::span("scoped_inner", "test");
        }
        assert_eq!(obs::current_request_id(), 42, "guard restores the outer id");
    }
    assert_eq!(obs::current_request_id(), 0);
    let _explicit = obs::span_req("explicit", "test", 7);
    drop(_explicit);

    obs::disable();
    let events = obs::drain_events();
    let by_name = |n: &str| events.iter().find(|e| e.name == n).expect("span recorded");
    assert_eq!(by_name("scoped").request_id, 42);
    assert_eq!(by_name("scoped_inner").request_id, 43);
    assert_eq!(by_name("explicit").request_id, 7);
}

#[test]
fn chrome_trace_export_is_valid_json_with_required_fields() {
    let _g = serial();
    obs::disable();
    let _ = obs::drain_events();
    obs::enable_capture();

    {
        let _a = obs::span_with("alpha", "test", || "{\"k\":1}".to_string());
        let _b = obs::span_req("beta", "test", 11);
    }

    obs::disable();
    let events = obs::drain_events();
    let text = obs::export::chrome_trace_json(&events, &[(1, "main".to_string())]);
    let doc = Json::parse(&text).expect("export is valid JSON");
    let arr = doc.as_arr().expect("top level is an array");
    assert!(arr.len() >= 3, "thread record + two spans");

    let meta = &arr[0];
    assert_eq!(meta.get("ph").and_then(Json::as_str), Some("M"));
    assert_eq!(meta.get("name").and_then(Json::as_str), Some("thread_name"));

    let mut saw_alpha = false;
    let mut saw_beta = false;
    let mut last_ts = f64::NEG_INFINITY;
    for ev in &arr[1..] {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        for field in ["ts", "dur"] {
            assert!(ev.get(field).and_then(Json::as_f64).is_some(), "numeric {field}");
        }
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        let ts = ev.get("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last_ts, "events sorted by ts");
        last_ts = ts;
        match ev.get("name").and_then(Json::as_str) {
            Some("alpha") => {
                saw_alpha = true;
                let args = ev.get("args").expect("alpha keeps its args");
                assert_eq!(args.get("k").and_then(Json::as_usize), Some(1));
            }
            Some("beta") => {
                saw_beta = true;
                let args = ev.get("args").expect("beta carries a request id");
                assert_eq!(args.get("request_id").and_then(Json::as_usize), Some(11));
            }
            _ => {}
        }
    }
    assert!(saw_alpha && saw_beta);
}
