//! Typed inference protocol v2, end to end: capability negotiation, GP
//! posterior variance against a dense oracle, sharded == in-process
//! agreement at every cut depth, artifact round-trips, and the
//! bad-frame-does-not-kill-the-worker regression.

use hck::coordinator::{BatchPolicy, PredictionService, Predictor};
use hck::gp::GpRegressor;
use hck::hkernel::{HConfig, HPredictor};
use hck::infer::{PredictRequest, Want};
use hck::kernels::Gaussian;
use hck::learn::{EngineSpec, TrainConfig};
use hck::linalg::{Cholesky, Mat};
use hck::model::{fit, load_any, Model, ModelSpec};
use hck::shard::ShardedPredictor;
use hck::util::rng::Rng;
use std::sync::Arc;

fn toy(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| (4.0 * x[(i, 0)]).sin() + 0.05 * rng.normal())
        .collect();
    (x, y)
}

fn hcfg(r: usize, seed: u64) -> HConfig {
    let mut cfg = HConfig::new(Gaussian::new(0.4), r).with_seed(seed);
    cfg.n0 = r;
    cfg.lambda_prime = 0.0;
    cfg
}

/// Satellite: exact small-n dense GP variance vs the hierarchical
/// batched pass, ≤ 1e-8. The oracle solves (K + λI) with a dense
/// Cholesky over the densified hierarchical kernel; the column is the
/// same k_hierarchical(X, x), so the comparison isolates the solver +
/// quadratic-form path.
#[test]
fn gp_variance_matches_dense_oracle() {
    let (x, y) = toy(80, 2, 1);
    let lambda = 0.05;
    let gp = GpRegressor::fit(&x, &y, hcfg(8, 2), lambda).unwrap();
    let mut rng = Rng::new(9);
    let q = Mat::from_fn(12, 2, |_, _| rng.uniform(-0.2, 1.2));
    let got = gp.variance(&q).unwrap();

    let f = gp.factors();
    let mut k = hck::hkernel::densify::densify(f);
    k.add_diag(lambda);
    let chol = Cholesky::new_jittered(&k, 10).unwrap();
    let prior = f.config.kind.diag_value();
    for i in 0..q.rows() {
        let v = HPredictor::column(f, q.row(i));
        let sol = chol.solve(&v);
        let quad: f64 = v.iter().zip(sol.iter()).map(|(a, b)| a * b).sum();
        let want = (prior - quad).max(0.0);
        assert!(
            (got[i] - want).abs() <= 1e-8 * (1.0 + want.abs()),
            "query {i}: {} vs dense {}",
            got[i],
            want
        );
        assert!(got[i] >= 0.0);
    }
}

/// The tentpole acceptance: GP variance round-trips through an HCKM
/// artifact and sharded serving, matching the in-process pass to ≤1e-10
/// at **every** cut depth; mean and routes agree too, and the mean-only
/// path is bitwise identical to the convenience surface.
#[test]
fn sharded_variance_and_routes_match_in_process_at_every_depth() {
    let (x, y) = toy(240, 3, 7);
    let train = hck::data::Dataset::new("toy", x, y, hck::data::Task::Regression).unwrap();
    let ranges: Vec<(f64, f64)> = (0..3).map(|_| (0.0, 1.0)).collect();
    let spec = ModelSpec::gp(hcfg(8, 3), 0.05).with_normalization(ranges);
    let model = fit(&spec, &train).unwrap();

    // Round-trip through the artifact first: the served variance must
    // come from persisted state, not the fitting session.
    let path = std::env::temp_dir().join(format!("hck_infer_{}.hckm", std::process::id()));
    let path = path.to_string_lossy().into_owned();
    model.save(&path).unwrap();
    let loaded = load_any(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let mut rng = Rng::new(5);
    let q = Mat::from_fn(40, 3, |_, _| rng.uniform(0.0, 1.0));
    let want_all = Want::mean_only().with_variance().with_leaf_route();
    let req = PredictRequest::new(q.clone(), want_all);
    let reference = loaded.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();
    let ref_routes = reference.routes.as_ref().unwrap();

    // Bitwise mean-only contract (artifact side).
    let mean_only = loaded.predict(&PredictRequest::mean_of(&q)).unwrap();
    assert_eq!(mean_only.mean.as_slice(), reference.mean.as_slice());

    let depth = loaded.hierarchical_predictor().unwrap().factors().tree.depth();
    for cut in 0..=depth {
        let sharded = ShardedPredictor::from_model(loaded.as_ref(), cut).unwrap();
        let got = sharded.predict(&req).unwrap();
        let got_var = got.variance.as_ref().unwrap();
        let got_routes = got.routes.as_ref().unwrap();
        for i in 0..q.rows() {
            assert!(
                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                "depth {cut} query {i} mean"
            );
            assert!(
                (got_var[i] - ref_var[i]).abs() <= 1e-10 * (1.0 + ref_var[i].abs()),
                "depth {cut} query {i} variance: {} vs {}",
                got_var[i],
                ref_var[i]
            );
            assert_eq!(
                (got_routes[i].rows_lo, got_routes[i].rows_hi),
                (ref_routes[i].rows_lo, ref_routes[i].rows_hi),
                "depth {cut} query {i} route"
            );
            assert!(got_routes[i].shard.is_some() && ref_routes[i].shard.is_none());
        }
    }
}

/// Mean-only requests through the typed surface reproduce the
/// convenience `predict_batch` path bitwise for every model kind.
#[test]
fn mean_only_requests_are_bitwise_identical_across_kinds() {
    let spec = hck::data::spec_by_name("cadata").unwrap();
    let (train, test) = hck::data::synthetic::generate(spec, 260, 40, 21);
    let specs = vec![
        ModelSpec::krr(TrainConfig::new(
            Gaussian::new(0.5),
            EngineSpec::Hierarchical { rank: 24 },
        )),
        ModelSpec::krr(TrainConfig::new(Gaussian::new(0.5), EngineSpec::Nystrom { rank: 24 })),
        ModelSpec::krr(TrainConfig::new(Gaussian::new(0.5), EngineSpec::Fourier { rank: 24 })),
        ModelSpec::gp(hcfg(16, 4), 0.05),
        ModelSpec::kpca(hcfg(16, 5), 4),
    ];
    for spec in specs {
        let model = fit(&spec, &train).unwrap();
        let via_batch = model.predict_batch(&test.x);
        let via_typed = model.predict(&PredictRequest::raw_mean(&test.x)).unwrap();
        assert_eq!(
            via_typed.mean.as_slice(),
            via_batch.as_slice(),
            "{}",
            model.schema().kind.name()
        );
    }
}

/// Satellite regression: malformed queries (wrong dim, zero rows, NaN)
/// produce typed BadRequest errors through the service and the workers
/// stay alive — including the sharded path behind the dynamic batcher.
#[test]
fn bad_requests_do_not_kill_serving_threads() {
    let (x, y) = toy(150, 3, 11);
    let train = hck::data::Dataset::new("toy", x, y, hck::data::Task::Regression).unwrap();
    let model = fit(&ModelSpec::gp(hcfg(8, 6), 0.05), &train).unwrap();
    let sharded = ShardedPredictor::from_model(model.as_ref(), 1).unwrap();
    assert!(sharded.shards() >= 2);
    assert!(sharded.capabilities().variance);
    let svc = PredictionService::start(Arc::new(sharded), BatchPolicy::default());

    // Wrong dimension.
    assert_eq!(
        svc.predict_typed(vec![0.5; 7], Want::mean_only()).unwrap_err().kind(),
        "bad_request"
    );
    // Non-finite feature.
    assert_eq!(
        svc.predict_typed(vec![0.5, f64::NAN, 0.5], Want::mean_only())
            .unwrap_err()
            .kind(),
        "bad_request"
    );
    // Empty.
    assert_eq!(
        svc.predict_typed(vec![], Want::mean_only()).unwrap_err().kind(),
        "bad_request"
    );
    // The loop is alive and still serves every capability.
    let reply = svc
        .predict_typed(
            vec![0.5, 0.5, 0.5],
            Want::mean_only().with_variance().with_leaf_route(),
        )
        .unwrap();
    assert!(reply.mean[0].is_finite());
    let var = reply.variance.unwrap();
    assert!(var.is_finite() && var >= 0.0);
    assert!(reply.route.unwrap().shard.is_some());
    svc.shutdown();
}

/// Capability negotiation through a live service: a mean-only engine
/// rejects variance requests with a typed error; the GP grants them.
#[test]
fn service_negotiates_capabilities_per_model() {
    let spec = hck::data::spec_by_name("cadata").unwrap();
    let (train, _) = hck::data::synthetic::generate(spec, 200, 10, 31);
    let nys = fit(
        &ModelSpec::krr(TrainConfig::new(Gaussian::new(0.5), EngineSpec::Nystrom { rank: 16 })),
        &train,
    )
    .unwrap();
    let svc = PredictionService::start_model(Arc::from(nys), BatchPolicy::default());
    assert!(!svc.capabilities().variance && !svc.capabilities().leaf_route);
    let err = svc
        .predict_typed(vec![0.1; train.d()], Want::mean_only().with_variance())
        .unwrap_err();
    assert_eq!(err.kind(), "unsupported");
    let ok = svc.predict_typed(vec![0.1; train.d()], Want::mean_only()).unwrap();
    assert_eq!(ok.mean.len(), 1);
    svc.shutdown();

    let gp = fit(&ModelSpec::gp(hcfg(12, 8), 0.05), &train).unwrap();
    let svc = PredictionService::start_model(Arc::from(gp), BatchPolicy::default());
    let caps = svc.capabilities();
    assert!(caps.variance && caps.leaf_route);
    let reply = svc
        .predict_typed(vec![0.1; train.d()], Want::mean_only().with_variance())
        .unwrap();
    assert!(reply.variance.unwrap() >= 0.0);
    svc.shutdown();
}
