//! Distributed serving acceptance: remote shard workers + the
//! balancing router against the in-process reference.
//!
//! - remote == in-process ≤1e-10 for mean **and** variance at every cut
//!   depth (the `HCKW` wire must be numerically invisible);
//! - killing a replicated worker mid-stream fails over to the replica
//!   and still returns correct results;
//! - killing an unreplicated worker yields a typed
//!   `PredictError::Shard` — never a panic, never NaN rows;
//! - seeded fault injection (`fault::install`) drives the resilience
//!   machinery deterministically: circuit breakers open on a flapping
//!   replica and recover half-open, a stalled replica is hedged to its
//!   sibling with bit-identical results, and draining a replica under
//!   load drops no request and retires it once outstanding work hits
//!   zero.

use hck::coordinator::Predictor;
use hck::hkernel::HConfig;
use hck::infer::{PredictRequest, Want};
use hck::kernels::Gaussian;
use hck::linalg::Mat;
use hck::model::{fit, load_any, Model, ModelSpec};
use hck::shard::fault::{self, FaultPlan};
use hck::shard::{
    boundary_nodes, split_predictor, RemoteShardedPredictor, RemoteWorker,
    RemoteWorkerClient, ResilienceConfig, ShardRouter,
};
use hck::util::rng::Rng;
use std::sync::Mutex;
use std::time::{Duration, Instant};

const TIMEOUT: Duration = Duration::from_millis(2000);

/// The fault plan is process-global; fault-driven tests serialize on
/// this so one test's plan never leaks into another's workers. Every
/// rule additionally carries a `worker=<addr>` selector, so the
/// fault-free tests running in parallel are never matched.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn fault_guard() -> std::sync::MutexGuard<'static, ()> {
    match FAULT_LOCK.lock() {
        Ok(g) => g,
        Err(poison) => poison.into_inner(),
    }
}

fn connect_resilient(
    model: &dyn Model,
    cut: usize,
    workers: &[String],
    cfg: ResilienceConfig,
) -> RemoteShardedPredictor {
    RemoteShardedPredictor::connect_with(router_at(model, cut), workers, TIMEOUT, cfg, None)
        .unwrap()
        .with_normalization(model.schema().normalization.clone())
}

fn toy(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0));
    let y: Vec<f64> =
        (0..n).map(|i| (4.0 * x[(i, 0)]).sin() + 0.05 * rng.normal()).collect();
    (x, y)
}

fn hcfg(r: usize, seed: u64) -> HConfig {
    let mut cfg = HConfig::new(Gaussian::new(0.4), r).with_seed(seed);
    cfg.n0 = r;
    cfg.lambda_prime = 0.0;
    cfg
}

/// Fit a GP artifact and round-trip it through disk, so the workers
/// serve persisted state like a real deployment.
fn gp_artifact(tag: &str) -> Box<dyn Model> {
    let (x, y) = toy(240, 3, 7);
    let train = hck::data::Dataset::new("toy", x, y, hck::data::Task::Regression).unwrap();
    let ranges: Vec<(f64, f64)> = (0..3).map(|_| (0.0, 1.0)).collect();
    let spec = ModelSpec::gp(hcfg(8, 3), 0.05).with_normalization(ranges);
    let model = fit(&spec, &train).unwrap();
    let path =
        std::env::temp_dir().join(format!("hck_remote_{tag}_{}.hckm", std::process::id()));
    let path = path.to_string_lossy().into_owned();
    model.save(&path).unwrap();
    let loaded = load_any(&path).unwrap();
    std::fs::remove_file(&path).ok();
    loaded
}

/// Serve a full replica (every shard of the cut) on an ephemeral
/// loopback port, with the model's shared variance state.
fn full_replica(model: &dyn Model, cut: usize) -> RemoteWorker {
    let pred = model.hierarchical_predictor().unwrap();
    let shards = split_predictor(pred, cut);
    RemoteWorker::serve("127.0.0.1:0", shards, model.variance_state()).unwrap()
}

fn router_at(model: &dyn Model, cut: usize) -> ShardRouter {
    let tree = &model.hierarchical_predictor().unwrap().factors().tree;
    ShardRouter::new(tree, &boundary_nodes(tree, cut))
}

fn connect(
    model: &dyn Model,
    cut: usize,
    workers: &[String],
) -> RemoteShardedPredictor {
    RemoteShardedPredictor::connect(router_at(model, cut), workers, TIMEOUT)
        .unwrap()
        .with_normalization(model.schema().normalization.clone())
}

#[test]
fn remote_matches_in_process_at_every_cut_depth() {
    let model = gp_artifact("depths");
    let mut rng = Rng::new(5);
    let q = Mat::from_fn(40, 3, |_, _| rng.uniform(0.0, 1.0));
    let req =
        PredictRequest::new(q.clone(), Want::mean_only().with_variance().with_leaf_route());
    let reference = model.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();
    let ref_routes = reference.routes.as_ref().unwrap();

    let depth = model.hierarchical_predictor().unwrap().factors().tree.depth();
    for cut in 0..=depth {
        let worker = full_replica(model.as_ref(), cut);
        let remote = connect(model.as_ref(), cut, &[worker.addr()]);
        assert_eq!(remote.shards(), remote.replica_counts().len());
        let got = remote.predict(&req).unwrap();
        let got_var = got.variance.as_ref().unwrap();
        let got_routes = got.routes.as_ref().unwrap();
        for i in 0..q.rows() {
            assert!(
                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                "depth {cut} query {i} mean: {} vs {}",
                got.mean[(i, 0)],
                reference.mean[(i, 0)]
            );
            assert!(
                (got_var[i] - ref_var[i]).abs() <= 1e-10 * (1.0 + ref_var[i].abs()),
                "depth {cut} query {i} variance: {} vs {}",
                got_var[i],
                ref_var[i]
            );
            assert_eq!(
                (got_routes[i].rows_lo, got_routes[i].rows_hi),
                (ref_routes[i].rows_lo, ref_routes[i].rows_hi),
                "depth {cut} query {i} route"
            );
            assert!(got_routes[i].shard.is_some());
        }
        worker.shutdown();
    }
}

#[test]
fn killed_replica_fails_over_with_correct_results() {
    let model = gp_artifact("failover");
    let cut = 1;
    let w1 = full_replica(model.as_ref(), cut);
    let w2 = full_replica(model.as_ref(), cut);
    let remote = connect(model.as_ref(), cut, &[w1.addr(), w2.addr()]);
    assert!(remote.replica_counts().iter().all(|&r| r == 2), "{:?}", remote.replica_counts());

    let mut rng = Rng::new(11);
    let q = Mat::from_fn(24, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q.clone(), Want::mean_only().with_variance());
    let reference = model.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();

    let check = |got: &hck::infer::PredictResponse, label: &str| {
        let got_var = got.variance.as_ref().unwrap();
        for i in 0..q.rows() {
            assert!(
                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                "{label} query {i} mean"
            );
            assert!(got.mean[(i, 0)].is_finite(), "{label} query {i}: NaN mean");
            assert!(
                (got_var[i] - ref_var[i]).abs() <= 1e-10 * (1.0 + ref_var[i].abs()),
                "{label} query {i} variance"
            );
        }
    };

    // Both replicas up: correct results, connections warm.
    check(&remote.predict(&req).unwrap(), "both replicas");
    // Kill one worker mid-stream. The router's next predict hits a dead
    // socket (or a refused reconnect) on that replica and must fail
    // over — repeatedly, so replica scoring can't route back into the
    // corpse.
    w1.shutdown();
    for round in 0..3 {
        check(&remote.predict(&req).unwrap(), &format!("post-kill round {round}"));
    }
    // The metrics view agrees: one worker unreachable, one up.
    let workers = remote.worker_metrics();
    assert_eq!(workers.len(), 2);
    assert_eq!(workers.iter().filter(|w| w.reachable).count(), 1);
    w2.shutdown();
}

#[test]
fn unreplicated_worker_death_is_a_typed_shard_error() {
    let model = gp_artifact("typed");
    let cut = 1;
    let worker = full_replica(model.as_ref(), cut);
    let remote = connect(model.as_ref(), cut, &[worker.addr()]);

    let mut rng = Rng::new(13);
    let q = Mat::from_fn(8, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q, Want::mean_only());
    assert!(remote.predict(&req).is_ok());

    worker.shutdown();
    // Every subsequent predict is a typed shard failure naming the
    // shard — never a panic, never NaN rows.
    for _ in 0..2 {
        let err = match remote.predict(&req) {
            Err(e) => e,
            Ok(_) => panic!("predict after worker death must fail with a typed error"),
        };
        assert_eq!(err.kind(), "shard_failure");
        assert!(
            err.message().contains("replica"),
            "error should describe exhausted replicas: {}",
            err.message()
        );
    }
}

#[test]
fn flapping_replica_opens_breaker_and_recovers_half_open() {
    let _serialized = fault_guard();
    let model = gp_artifact("breaker");
    let cut = 1;
    let w1 = full_replica(model.as_ref(), cut);
    let w2 = full_replica(model.as_ref(), cut);
    let cfg = ResilienceConfig {
        breaker_failures: 1,
        breaker_cooldown: Duration::from_millis(0),
        hedge_after_ms: Some(0),
        ..Default::default()
    };
    let remote = connect_resilient(model.as_ref(), cut, &[w1.addr(), w2.addr()], cfg);

    let mut rng = Rng::new(21);
    let q = Mat::from_fn(16, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q.clone(), Want::mean_only().with_variance());
    let reference = model.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();
    let check = |got: &hck::infer::PredictResponse, label: &str| {
        let got_var = got.variance.as_ref().unwrap();
        for i in 0..q.rows() {
            assert!(
                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                "{label} query {i} mean"
            );
            assert!(
                (got_var[i] - ref_var[i]).abs() <= 1e-10 * (1.0 + ref_var[i].abs()),
                "{label} query {i} variance"
            );
        }
    };

    // Every predict to w1 fails at the client edge: the sub-batch fails
    // over to w2 (correct rows), and one failure is enough to open w1's
    // breaker under breaker_failures = 1.
    fault::install(Some(
        FaultPlan::parse(&format!("fail:site=client,op=predict,worker={}", w1.addr()))
            .unwrap(),
    ));
    for round in 0..2 {
        check(&remote.predict(&req).unwrap(), &format!("flapping round {round}"));
    }
    let opens_while_failing: u64 =
        remote.worker_metrics().iter().map(|w| w.breaker_opens).sum();
    assert!(opens_while_failing >= 1, "breaker never opened: {opens_while_failing}");

    // Fault gone: the zero cooldown admits a half-open probe on the next
    // predict, the probe succeeds, and the breaker closes — no further
    // opens, both replicas reachable and active.
    fault::clear();
    for round in 0..3 {
        check(&remote.predict(&req).unwrap(), &format!("recovered round {round}"));
    }
    let workers = remote.worker_metrics();
    assert_eq!(
        workers.iter().map(|w| w.breaker_opens).sum::<u64>(),
        opens_while_failing,
        "breaker reopened after the fault was cleared"
    );
    assert!(workers.iter().all(|w| w.reachable && w.state == "active"));
    w1.shutdown();
    w2.shutdown();
}

#[test]
fn stalled_replica_is_hedged_to_its_sibling() {
    let _serialized = fault_guard();
    let model = gp_artifact("hedge");
    let cut = 1;
    let w1 = full_replica(model.as_ref(), cut);
    let w2 = full_replica(model.as_ref(), cut);
    let cfg = ResilienceConfig {
        hedge_after_ms: Some(5),
        // The stalled replica must stay in rotation: this test is about
        // hedging, not breakers.
        breaker_failures: 100,
        ..Default::default()
    };
    let remote = connect_resilient(model.as_ref(), cut, &[w1.addr(), w2.addr()], cfg);

    let mut rng = Rng::new(23);
    let q = Mat::from_fn(24, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q.clone(), Want::mean_only().with_variance());
    let reference = model.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();

    // Both workers are idle (equal load scores), so replica order is
    // registry order and w1 is every shard's primary. Stall it well past
    // the hedge deadline; the hedge must win long before the stall ends.
    fault::install(Some(
        FaultPlan::parse(&format!(
            "stall:site=client,op=predict,worker={},ms=500",
            w1.addr()
        ))
        .unwrap(),
    ));
    let t = Instant::now();
    let got = remote.predict(&req).unwrap();
    let elapsed = t.elapsed();
    fault::clear();

    let got_var = got.variance.as_ref().unwrap();
    for i in 0..q.rows() {
        assert!(
            (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
            "hedged query {i} mean: {} vs {}",
            got.mean[(i, 0)],
            reference.mean[(i, 0)]
        );
        assert!(
            (got_var[i] - ref_var[i]).abs() <= 1e-10 * (1.0 + ref_var[i].abs()),
            "hedged query {i} variance"
        );
    }
    let hedges: u64 = remote.worker_metrics().iter().map(|w| w.hedges).sum();
    assert!(hedges >= 1, "no hedge fired against the stalled replica");
    assert!(
        elapsed < Duration::from_millis(400),
        "hedge did not win over the 500ms stall: {elapsed:?}"
    );
    w1.shutdown();
    w2.shutdown();
}

#[test]
fn drain_under_load_drops_nothing_and_retires() {
    let _serialized = fault_guard();
    let model = gp_artifact("drain");
    let cut = 1;
    let w1 = full_replica(model.as_ref(), cut);
    let w2 = full_replica(model.as_ref(), cut);
    let cfg = ResilienceConfig {
        hedge_after_ms: Some(0),
        breaker_failures: 100,
        ..Default::default()
    };
    let remote = connect_resilient(model.as_ref(), cut, &[w1.addr(), w2.addr()], cfg);

    let mut rng = Rng::new(29);
    let q = Mat::from_fn(24, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q.clone(), Want::mean_only().with_variance());
    let reference = model.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();

    // Slow the first few evaluations at w1's worker edge so in-flight
    // work genuinely overlaps the drain command.
    fault::install(Some(
        FaultPlan::parse(&format!(
            "stall:site=worker,op=predict,worker={},ms=30,times=4",
            w1.addr()
        ))
        .unwrap(),
    ));
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                s.spawn(|| {
                    for _ in 0..3 {
                        let got = remote.predict(&req).unwrap();
                        let got_var = got.variance.as_ref().unwrap();
                        for i in 0..q.rows() {
                            assert!(
                                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                                "under-drain query {i} mean (order/row integrity)"
                            );
                            assert!(
                                (got_var[i] - ref_var[i]).abs()
                                    <= 1e-10 * (1.0 + ref_var[i].abs()),
                                "under-drain query {i} variance"
                            );
                        }
                    }
                })
            })
            .collect();
        // Drain w1 while that traffic is in flight: new sub-batches stop
        // routing to it, in-flight ones finish.
        remote.drain_worker(&w1.addr()).unwrap();
        for h in handles {
            h.join().unwrap();
        }
    });
    fault::clear();

    // All traffic finished, so outstanding is zero and a reconcile pass
    // retires the drained replica.
    remote.reconcile();
    let states = remote.worker_states();
    assert_eq!(states.len(), 2);
    for (addr, state, outstanding) in &states {
        if *addr == w1.addr() {
            assert_eq!(*state, "retired", "drained worker must retire, got {state}");
            assert_eq!(*outstanding, 0);
        } else {
            assert_eq!(*state, "active");
        }
    }
    let drains: u64 = remote.worker_metrics().iter().map(|w| w.drains).sum();
    assert_eq!(drains, 1);

    // The worker-side gate holds for everyone: a fresh client asking the
    // drained worker directly gets the typed draining error.
    let direct = RemoteWorkerClient::new(&w1.addr(), TIMEOUT);
    let err = match direct.predict_shard(0, &q, Want::mean_only()) {
        Err(e) => e,
        Ok(_) => panic!("a drained worker must refuse new predicts"),
    };
    assert_eq!(err.kind(), "draining");

    // Traffic after the drain still serves correctly from w2 alone.
    let got = remote.predict(&req).unwrap();
    assert!((got.mean[(0, 0)] - reference.mean[(0, 0)]).abs() <= 1e-10);
    w1.shutdown();
    w2.shutdown();
}

#[test]
fn workers_attach_and_drain_at_runtime() {
    let model = gp_artifact("attach");
    let cut = 1;
    let w1 = full_replica(model.as_ref(), cut);
    let w2 = full_replica(model.as_ref(), cut);
    let remote = connect(model.as_ref(), cut, &[w1.addr()]);

    let mut rng = Rng::new(31);
    let q = Mat::from_fn(16, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q.clone(), Want::mean_only());
    let reference = model.predict(&req).unwrap();
    let check = |got: &hck::infer::PredictResponse, label: &str| {
        for i in 0..q.rows() {
            assert!(
                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                "{label} query {i}"
            );
        }
    };
    check(&remote.predict(&req).unwrap(), "single replica");

    // Attach the second replica at runtime; every shard gains a replica
    // and results stay identical.
    remote.attach_worker(&w2.addr()).unwrap();
    assert!(
        remote.replica_counts().iter().all(|&r| r == 2),
        "{:?}",
        remote.replica_counts()
    );
    check(&remote.predict(&req).unwrap(), "after attach");
    // Double-attach of a live worker is refused.
    assert!(remote.attach_worker(&w2.addr()).is_err());

    // Drain the original, reconcile, and the replacement carries the
    // whole topology.
    remote.drain_worker(&w1.addr()).unwrap();
    remote.reconcile();
    let states = remote.worker_states();
    assert!(states
        .iter()
        .any(|(addr, state, _)| *addr == w1.addr() && *state == "retired"));
    check(&remote.predict(&req).unwrap(), "after drain");
    // Draining the last active replica would uncover every shard.
    assert!(remote.drain_worker(&w2.addr()).is_err());

    // The admin surface agrees with worker_states().
    let admin = remote.admin("workers", "").unwrap();
    let rows = admin.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    assert!(remote.admin("nonsense", "").is_err());
    w1.shutdown();
    w2.shutdown();
}
