//! Distributed serving acceptance: remote shard workers + the
//! balancing router against the in-process reference.
//!
//! - remote == in-process ≤1e-10 for mean **and** variance at every cut
//!   depth (the `HCKW` wire must be numerically invisible);
//! - killing a replicated worker mid-stream fails over to the replica
//!   and still returns correct results;
//! - killing an unreplicated worker yields a typed
//!   `PredictError::Shard` — never a panic, never NaN rows.

use hck::coordinator::Predictor;
use hck::hkernel::HConfig;
use hck::infer::{PredictRequest, Want};
use hck::kernels::Gaussian;
use hck::linalg::Mat;
use hck::model::{fit, load_any, Model, ModelSpec};
use hck::shard::{
    boundary_nodes, split_predictor, RemoteShardedPredictor, RemoteWorker, ShardRouter,
};
use hck::util::rng::Rng;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_millis(2000);

fn toy(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0));
    let y: Vec<f64> =
        (0..n).map(|i| (4.0 * x[(i, 0)]).sin() + 0.05 * rng.normal()).collect();
    (x, y)
}

fn hcfg(r: usize, seed: u64) -> HConfig {
    let mut cfg = HConfig::new(Gaussian::new(0.4), r).with_seed(seed);
    cfg.n0 = r;
    cfg.lambda_prime = 0.0;
    cfg
}

/// Fit a GP artifact and round-trip it through disk, so the workers
/// serve persisted state like a real deployment.
fn gp_artifact(tag: &str) -> Box<dyn Model> {
    let (x, y) = toy(240, 3, 7);
    let train = hck::data::Dataset::new("toy", x, y, hck::data::Task::Regression).unwrap();
    let ranges: Vec<(f64, f64)> = (0..3).map(|_| (0.0, 1.0)).collect();
    let spec = ModelSpec::gp(hcfg(8, 3), 0.05).with_normalization(ranges);
    let model = fit(&spec, &train).unwrap();
    let path =
        std::env::temp_dir().join(format!("hck_remote_{tag}_{}.hckm", std::process::id()));
    let path = path.to_string_lossy().into_owned();
    model.save(&path).unwrap();
    let loaded = load_any(&path).unwrap();
    std::fs::remove_file(&path).ok();
    loaded
}

/// Serve a full replica (every shard of the cut) on an ephemeral
/// loopback port, with the model's shared variance state.
fn full_replica(model: &dyn Model, cut: usize) -> RemoteWorker {
    let pred = model.hierarchical_predictor().unwrap();
    let shards = split_predictor(pred, cut);
    RemoteWorker::serve("127.0.0.1:0", shards, model.variance_state()).unwrap()
}

fn router_at(model: &dyn Model, cut: usize) -> ShardRouter {
    let tree = &model.hierarchical_predictor().unwrap().factors().tree;
    ShardRouter::new(tree, &boundary_nodes(tree, cut))
}

fn connect(
    model: &dyn Model,
    cut: usize,
    workers: &[String],
) -> RemoteShardedPredictor {
    RemoteShardedPredictor::connect(router_at(model, cut), workers, TIMEOUT)
        .unwrap()
        .with_normalization(model.schema().normalization.clone())
}

#[test]
fn remote_matches_in_process_at_every_cut_depth() {
    let model = gp_artifact("depths");
    let mut rng = Rng::new(5);
    let q = Mat::from_fn(40, 3, |_, _| rng.uniform(0.0, 1.0));
    let req =
        PredictRequest::new(q.clone(), Want::mean_only().with_variance().with_leaf_route());
    let reference = model.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();
    let ref_routes = reference.routes.as_ref().unwrap();

    let depth = model.hierarchical_predictor().unwrap().factors().tree.depth();
    for cut in 0..=depth {
        let worker = full_replica(model.as_ref(), cut);
        let remote = connect(model.as_ref(), cut, &[worker.addr()]);
        assert_eq!(remote.shards(), remote.replica_counts().len());
        let got = remote.predict(&req).unwrap();
        let got_var = got.variance.as_ref().unwrap();
        let got_routes = got.routes.as_ref().unwrap();
        for i in 0..q.rows() {
            assert!(
                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                "depth {cut} query {i} mean: {} vs {}",
                got.mean[(i, 0)],
                reference.mean[(i, 0)]
            );
            assert!(
                (got_var[i] - ref_var[i]).abs() <= 1e-10 * (1.0 + ref_var[i].abs()),
                "depth {cut} query {i} variance: {} vs {}",
                got_var[i],
                ref_var[i]
            );
            assert_eq!(
                (got_routes[i].rows_lo, got_routes[i].rows_hi),
                (ref_routes[i].rows_lo, ref_routes[i].rows_hi),
                "depth {cut} query {i} route"
            );
            assert!(got_routes[i].shard.is_some());
        }
        worker.shutdown();
    }
}

#[test]
fn killed_replica_fails_over_with_correct_results() {
    let model = gp_artifact("failover");
    let cut = 1;
    let w1 = full_replica(model.as_ref(), cut);
    let w2 = full_replica(model.as_ref(), cut);
    let remote = connect(model.as_ref(), cut, &[w1.addr(), w2.addr()]);
    assert!(remote.replica_counts().iter().all(|&r| r == 2), "{:?}", remote.replica_counts());

    let mut rng = Rng::new(11);
    let q = Mat::from_fn(24, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q.clone(), Want::mean_only().with_variance());
    let reference = model.predict(&req).unwrap();
    let ref_var = reference.variance.as_ref().unwrap();

    let check = |got: &hck::infer::PredictResponse, label: &str| {
        let got_var = got.variance.as_ref().unwrap();
        for i in 0..q.rows() {
            assert!(
                (got.mean[(i, 0)] - reference.mean[(i, 0)]).abs()
                    <= 1e-10 * (1.0 + reference.mean[(i, 0)].abs()),
                "{label} query {i} mean"
            );
            assert!(got.mean[(i, 0)].is_finite(), "{label} query {i}: NaN mean");
            assert!(
                (got_var[i] - ref_var[i]).abs() <= 1e-10 * (1.0 + ref_var[i].abs()),
                "{label} query {i} variance"
            );
        }
    };

    // Both replicas up: correct results, connections warm.
    check(&remote.predict(&req).unwrap(), "both replicas");
    // Kill one worker mid-stream. The router's next predict hits a dead
    // socket (or a refused reconnect) on that replica and must fail
    // over — repeatedly, so replica scoring can't route back into the
    // corpse.
    w1.shutdown();
    for round in 0..3 {
        check(&remote.predict(&req).unwrap(), &format!("post-kill round {round}"));
    }
    // The metrics view agrees: one worker unreachable, one up.
    let workers = remote.worker_metrics();
    assert_eq!(workers.len(), 2);
    assert_eq!(workers.iter().filter(|w| w.reachable).count(), 1);
    w2.shutdown();
}

#[test]
fn unreplicated_worker_death_is_a_typed_shard_error() {
    let model = gp_artifact("typed");
    let cut = 1;
    let worker = full_replica(model.as_ref(), cut);
    let remote = connect(model.as_ref(), cut, &[worker.addr()]);

    let mut rng = Rng::new(13);
    let q = Mat::from_fn(8, 3, |_, _| rng.uniform(0.0, 1.0));
    let req = PredictRequest::new(q, Want::mean_only());
    assert!(remote.predict(&req).is_ok());

    worker.shutdown();
    // Every subsequent predict is a typed shard failure naming the
    // shard — never a panic, never NaN rows.
    for _ in 0..2 {
        let err = match remote.predict(&req) {
            Err(e) => e,
            Ok(_) => panic!("predict after worker death must fail with a typed error"),
        };
        assert_eq!(err.kind(), "shard_failure");
        assert!(
            err.message().contains("replica"),
            "error should describe exhausted replicas: {}",
            err.message()
        );
    }
}
