//! Adversarial wire frames against the line protocol.
//!
//! Every malformed v1/v2 frame must come back as a **typed error
//! frame** — never a panic, never a dropped connection — and the
//! service must keep serving well-formed frames afterwards. This is the
//! regression suite for the de-unwrapped frame-handling path
//! (`coordinator::protocol` + the `serve_request` pipeline): the lint
//! bans panic idioms on the serving path, and this test pins the
//! behavior the ban protects.

use hck::coordinator::protocol::handle_line;
use hck::coordinator::{BatchPolicy, PredictionService};
use hck::data::{Dataset, Task};
use hck::hkernel::HConfig;
use hck::kernels::Gaussian;
use hck::linalg::Mat;
use hck::model::{fit, ModelSpec};
use hck::util::json::Json;
use hck::util::rng::Rng;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn gp_service() -> PredictionService {
    let mut rng = Rng::new(17);
    let x = Mat::from_fn(160, 3, |_, _| rng.uniform(0.0, 1.0));
    let y: Vec<f64> = (0..160)
        .map(|i| (x[(i, 0)] * 2.0 + x[(i, 1)]).sin() + 0.02 * rng.normal())
        .collect();
    let train = Dataset::new("adv", x, y, Task::Regression).unwrap();
    let mut cfg = HConfig::new(Gaussian::new(0.6), 8).with_seed(23);
    cfg.n0 = 8;
    let model = fit(&ModelSpec::gp(cfg, 0.05), &train).unwrap();
    PredictionService::start_model(Arc::from(model), BatchPolicy::default())
}

/// The error frame contract: an `"error"` object with a `kind` tag for
/// v2 frames, a plain string for v1/transport-level failures.
fn error_kind(reply: &Json) -> Option<String> {
    let err = reply.get("error")?;
    match err.get("kind") {
        Some(k) => k.as_str().map(str::to_string),
        None => err.as_str().map(|_| "v1".to_string()),
    }
}

#[test]
fn adversarial_frames_get_typed_errors_and_service_survives() {
    let svc = gp_service();
    let stop = AtomicBool::new(false);

    // (frame, expected kind) — "v1" marks the untagged v1 string form.
    let cases: &[(&str, &str)] = &[
        // Transport-level garbage.
        ("{not json", "v1"),
        ("[1, 2, 3", "v1"),
        ("\"just a string\"", "v1"),
        // v1 frames.
        ("{}", "v1"),
        (r#"{"features": "nope"}"#, "v1"),
        (r#"{"features": [0.5, 0.5]}"#, "v1"),
        (r#"{"features": [0.5, 0.5, 0.5, 0.5]}"#, "v1"),
        // v2 framing violations.
        (r#"{"v": 2}"#, "bad_request"),
        (r#"{"v": 2, "queries": "nope"}"#, "bad_request"),
        (r#"{"v": 2, "queries": []}"#, "bad_request"),
        (r#"{"v": 2, "queries": [[0.1, 0.2, 0.3], "rogue"]}"#, "bad_request"),
        (r#"{"v": 2, "queries": [[0.1, 0.2]]}"#, "bad_request"),
        (r#"{"queries": [[0.1, null, 0.3]]}"#, "bad_request"),
        // want-object violations.
        (r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": 7}"#, "bad_request"),
        (
            r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": {"mean": false}}"#,
            "bad_request",
        ),
        (
            r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": {"variance": "yes"}}"#,
            "bad_request",
        ),
        (
            r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": {"gradient": true}}"#,
            "bad_request",
        ),
        // Unknown command.
        (r#"{"cmd": "reboot"}"#, "v1"),
    ];
    for (frame, want_kind) in cases {
        let reply = handle_line(frame, &svc, &stop);
        let kind = error_kind(&reply)
            .unwrap_or_else(|| panic!("frame {frame:?} produced no error: {reply:?}"));
        assert_eq!(&kind, want_kind, "frame {frame:?} replied {reply:?}");
        assert!(!stop.load(std::sync::atomic::Ordering::SeqCst));
    }

    // Frame-level request ids are echoed even on error frames, so
    // pipelined clients can correlate rejections.
    let reply = handle_line(r#"{"v": 2, "request_id": 41, "queries": []}"#, &svc, &stop);
    assert_eq!(error_kind(&reply).as_deref(), Some("bad_request"));
    assert_eq!(reply.get("request_id").and_then(|r| r.as_f64()), Some(41.0));

    // A frame with one bad row among good rows is rejected atomically:
    // nothing is enqueued, so the request counter does not move.
    let before = svc.snapshot().requests;
    let reply = handle_line(
        r#"{"v": 2, "queries": [[0.1, 0.2, 0.3], [0.4, 0.5]]}"#,
        &svc,
        &stop,
    );
    assert_eq!(error_kind(&reply).as_deref(), Some("bad_request"));
    assert_eq!(svc.snapshot().requests, before);

    // After the whole gauntlet the loop still serves both protocols.
    let v1 = handle_line(r#"{"features": [0.5, 0.5, 0.5]}"#, &svc, &stop);
    assert!(v1.get("error").is_none(), "v1 after gauntlet: {v1:?}");
    assert_eq!(v1.get("prediction").unwrap().to_f64s().unwrap().len(), 1);

    let v2 = handle_line(
        r#"{"v": 2, "queries": [[0.5, 0.5, 0.5], [0.2, 0.8, 0.1]], "want": {"variance": true}}"#,
        &svc,
        &stop,
    );
    assert!(v2.get("error").is_none(), "v2 after gauntlet: {v2:?}");
    assert_eq!(v2.get("mean").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v2.get("variance").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v2.get("request_ids").unwrap().as_arr().unwrap().len(), 2);

    // The shutdown command is the only frame allowed to flip the stop
    // flag — pin that contract last.
    let bye = handle_line(r#"{"cmd": "shutdown"}"#, &svc, &stop);
    assert_eq!(bye.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert!(stop.load(std::sync::atomic::Ordering::SeqCst));
    svc.shutdown();
}
