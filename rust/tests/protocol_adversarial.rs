//! Adversarial wire frames against the line protocol.
//!
//! Every malformed v1/v2 frame must come back as a **typed error
//! frame** — never a panic, never a dropped connection — and the
//! service must keep serving well-formed frames afterwards. This is the
//! regression suite for the de-unwrapped frame-handling path
//! (`coordinator::protocol` + the `serve_request` pipeline): the lint
//! bans panic idioms on the serving path, and this test pins the
//! behavior the ban protects.

use hck::coordinator::protocol::handle_line;
use hck::coordinator::{BatchPolicy, PredictionService};
use hck::data::{Dataset, Task};
use hck::hkernel::HConfig;
use hck::infer::Want;
use hck::kernels::Gaussian;
use hck::linalg::Mat;
use hck::model::{fit, Model, ModelSpec};
use hck::shard::{split_predictor, RemoteWorker, RemoteWorkerClient};
use hck::util::json::Json;
use hck::util::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

fn gp_model() -> Box<dyn Model> {
    let mut rng = Rng::new(17);
    let x = Mat::from_fn(160, 3, |_, _| rng.uniform(0.0, 1.0));
    let y: Vec<f64> = (0..160)
        .map(|i| (x[(i, 0)] * 2.0 + x[(i, 1)]).sin() + 0.02 * rng.normal())
        .collect();
    let train = Dataset::new("adv", x, y, Task::Regression).unwrap();
    let mut cfg = HConfig::new(Gaussian::new(0.6), 8).with_seed(23);
    cfg.n0 = 8;
    fit(&ModelSpec::gp(cfg, 0.05), &train).unwrap()
}

fn gp_service() -> PredictionService {
    PredictionService::start_model(Arc::from(gp_model()), BatchPolicy::default())
}

/// The error frame contract: an `"error"` object with a `kind` tag for
/// v2 frames, a plain string for v1/transport-level failures.
fn error_kind(reply: &Json) -> Option<String> {
    let err = reply.get("error")?;
    match err.get("kind") {
        Some(k) => k.as_str().map(str::to_string),
        None => err.as_str().map(|_| "v1".to_string()),
    }
}

#[test]
fn adversarial_frames_get_typed_errors_and_service_survives() {
    let svc = gp_service();
    let stop = AtomicBool::new(false);

    // (frame, expected kind) — "v1" marks the untagged v1 string form.
    let cases: &[(&str, &str)] = &[
        // Transport-level garbage.
        ("{not json", "v1"),
        ("[1, 2, 3", "v1"),
        ("\"just a string\"", "v1"),
        // v1 frames.
        ("{}", "v1"),
        (r#"{"features": "nope"}"#, "v1"),
        (r#"{"features": [0.5, 0.5]}"#, "v1"),
        (r#"{"features": [0.5, 0.5, 0.5, 0.5]}"#, "v1"),
        // v2 framing violations.
        (r#"{"v": 2}"#, "bad_request"),
        (r#"{"v": 2, "queries": "nope"}"#, "bad_request"),
        (r#"{"v": 2, "queries": []}"#, "bad_request"),
        (r#"{"v": 2, "queries": [[0.1, 0.2, 0.3], "rogue"]}"#, "bad_request"),
        (r#"{"v": 2, "queries": [[0.1, 0.2]]}"#, "bad_request"),
        (r#"{"queries": [[0.1, null, 0.3]]}"#, "bad_request"),
        // want-object violations.
        (r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": 7}"#, "bad_request"),
        (
            r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": {"mean": false}}"#,
            "bad_request",
        ),
        (
            r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": {"variance": "yes"}}"#,
            "bad_request",
        ),
        (
            r#"{"v": 2, "queries": [[0.1, 0.2, 0.3]], "want": {"gradient": true}}"#,
            "bad_request",
        ),
        // Unknown command.
        (r#"{"cmd": "reboot"}"#, "v1"),
    ];
    for (frame, want_kind) in cases {
        let reply = handle_line(frame, &svc, &stop);
        let kind = error_kind(&reply)
            .unwrap_or_else(|| panic!("frame {frame:?} produced no error: {reply:?}"));
        assert_eq!(&kind, want_kind, "frame {frame:?} replied {reply:?}");
        assert!(!stop.load(std::sync::atomic::Ordering::SeqCst));
    }

    // Frame-level request ids are echoed even on error frames, so
    // pipelined clients can correlate rejections.
    let reply = handle_line(r#"{"v": 2, "request_id": 41, "queries": []}"#, &svc, &stop);
    assert_eq!(error_kind(&reply).as_deref(), Some("bad_request"));
    assert_eq!(reply.get("request_id").and_then(|r| r.as_f64()), Some(41.0));

    // A frame with one bad row among good rows is rejected atomically:
    // nothing is enqueued, so the request counter does not move.
    let before = svc.snapshot().requests;
    let reply = handle_line(
        r#"{"v": 2, "queries": [[0.1, 0.2, 0.3], [0.4, 0.5]]}"#,
        &svc,
        &stop,
    );
    assert_eq!(error_kind(&reply).as_deref(), Some("bad_request"));
    assert_eq!(svc.snapshot().requests, before);

    // After the whole gauntlet the loop still serves both protocols.
    let v1 = handle_line(r#"{"features": [0.5, 0.5, 0.5]}"#, &svc, &stop);
    assert!(v1.get("error").is_none(), "v1 after gauntlet: {v1:?}");
    assert_eq!(v1.get("prediction").unwrap().to_f64s().unwrap().len(), 1);

    let v2 = handle_line(
        r#"{"v": 2, "queries": [[0.5, 0.5, 0.5], [0.2, 0.8, 0.1]], "want": {"variance": true}}"#,
        &svc,
        &stop,
    );
    assert!(v2.get("error").is_none(), "v2 after gauntlet: {v2:?}");
    assert_eq!(v2.get("mean").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v2.get("variance").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v2.get("request_ids").unwrap().as_arr().unwrap().len(), 2);

    // The shutdown command is the only frame allowed to flip the stop
    // flag — pin that contract last.
    let bye = handle_line(r#"{"cmd": "shutdown"}"#, &svc, &stop);
    assert_eq!(bye.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert!(stop.load(std::sync::atomic::Ordering::SeqCst));
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// Remote shard-worker wire gauntlet (the binary HCKW protocol)
// ---------------------------------------------------------------------------

/// Read one raw `HCKW` reply frame off a test socket. `None` means the
/// worker (rightfully) hung up instead of replying.
fn read_raw_reply(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut magic = [0u8; 4];
    s.read_exact(&mut magic).ok()?;
    assert_eq!(&magic, b"HCKW", "reply frame must carry the wire magic");
    let mut lenb = [0u8; 8];
    s.read_exact(&mut lenb).ok()?;
    let len = u64::from_le_bytes(lenb);
    assert!(len > 0 && len <= 1 << 28, "reply length {len} out of range");
    let mut payload = vec![0u8; len as usize];
    s.read_exact(&mut payload).ok()?;
    Some(payload)
}

/// A reply payload must be a typed `REPLY_ERR` (0x82) frame with the
/// `bad_request` kind (1) and a message containing `needle`.
fn assert_bad_request(payload: &[u8], needle: &str, label: &str) {
    assert_eq!(payload[0], 0x82, "{label}: want REPLY_ERR, got tag {:#x}", payload[0]);
    assert_eq!(payload[1], 1, "{label}: want bad_request kind, got {}", payload[1]);
    let text = String::from_utf8_lossy(payload);
    assert!(text.contains(needle), "{label}: message should contain {needle:?}: {text}");
}

#[test]
fn remote_worker_survives_malformed_wire_frames() {
    let model = gp_model();
    let pred = model.hierarchical_predictor().expect("gp model is hierarchical");
    let shards = split_predictor(pred, 1);
    let n_shards = shards.len();
    let worker = RemoteWorker::serve("127.0.0.1:0", shards, model.variance_state())
        .expect("worker binds an ephemeral loopback port");
    let addr = worker.addr();

    // 1. Wrong magic: the worker replies with a typed bad_request frame,
    //    then drops that connection (the stream offset is unknowable).
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"XXXX").unwrap();
        s.write_all(&8u64.to_le_bytes()).unwrap();
        s.write_all(&[0u8; 8]).unwrap();
        let payload = read_raw_reply(&mut s).expect("wrong magic earns a typed reject");
        assert_bad_request(&payload, "malformed frame", "wrong magic");
        let mut one = [0u8; 1];
        assert_eq!(s.read(&mut one).unwrap_or(0), 0, "connection closes after bad magic");
    }

    // 2. Oversized claimed length: rejected before any allocation, with
    //    a typed frame naming the violation.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"HCKW").unwrap();
        s.write_all(&(1u64 << 60).to_le_bytes()).unwrap();
        let payload = read_raw_reply(&mut s).expect("oversized length earns a typed reject");
        assert_bad_request(&payload, "length", "oversized length");
    }

    // 3. Truncated length prefix, then disconnect: nothing to reply to,
    //    the worker just reaps the connection.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"HCKW").unwrap();
        s.write_all(&[1, 2, 3]).unwrap();
        drop(s);
    }

    // 4. Disconnect mid-payload: header promises 64 bytes, 10 arrive.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"HCKW").unwrap();
        s.write_all(&64u64.to_le_bytes()).unwrap();
        s.write_all(&[7u8; 10]).unwrap();
        drop(s);
    }

    // 5. A well-framed frame with an unknown command tag: typed reject,
    //    but the framing is intact so the connection stays usable.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"HCKW").unwrap();
        s.write_all(&1u64.to_le_bytes()).unwrap();
        s.write_all(&[99]).unwrap();
        let payload = read_raw_reply(&mut s).expect("unknown tag earns a typed reject");
        assert_eq!(payload[0], 0x82, "unknown tag: want REPLY_ERR");
        // Same connection, now a valid hello (tag 3): still served.
        s.write_all(b"HCKW").unwrap();
        s.write_all(&1u64.to_le_bytes()).unwrap();
        s.write_all(&[3]).unwrap();
        let payload = read_raw_reply(&mut s).expect("hello after reject still answered");
        assert_eq!(payload[0], 0x84, "want REPLY_HELLO after an in-band reject");
    }

    // After the whole gauntlet, the worker still serves real requests
    // through the typed client — the abuse cost only its own sockets.
    let client = RemoteWorkerClient::new(&addr, Duration::from_millis(2000));
    let hello = client.hello().expect("worker alive after gauntlet");
    assert_eq!(hello.shards.len(), n_shards);
    assert_eq!(hello.dim, 3);
    let q = Mat::from_fn(3, 3, |_, j| 0.2 + 0.1 * j as f64);
    let block = client
        .predict_shard(hello.shards[0].0, &q, Want::mean_only())
        .expect("predict after gauntlet");
    assert_eq!(block.mean.rows(), 3);
    assert!(block.mean.row(0).iter().all(|v| v.is_finite()));
    let stats = client.stats().expect("stats after gauntlet");
    assert_eq!(stats.len(), n_shards);
    worker.shutdown();
}
