//! ISSUE 2 acceptance: sharded serving must be indistinguishable (≤1e-10,
//! request-order stable) from the single-replica Algorithm-3 path — for
//! every shard depth from 0 (one shard = whole tree) through the leaf
//! level (one shard per leaf), for multi-output weight matrices, and
//! under concurrent clients through the dynamic batcher.

use hck::coordinator::{BatchPolicy, PredictionService, Predictor};
use hck::hkernel::{HConfig, HFactors, HPredictor};
use hck::kernels::{Gaussian, KernelKind, Laplace};
use hck::linalg::Mat;
use hck::partition::SplitRule;
use hck::shard::{boundary_nodes, split_predictor, ShardRouter, ShardedPredictor};
use hck::util::rng::Rng;
use std::sync::Arc;

#[allow(clippy::too_many_arguments)]
fn fitted(
    n: usize,
    d: usize,
    r: usize,
    n0: usize,
    m: usize,
    kind: KernelKind,
    rule: SplitRule,
    seed: u64,
) -> (Arc<HFactors>, HPredictor) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0));
    let mut cfg = HConfig::new(kind, r).with_seed(seed * 7 + 3).with_rule(rule);
    cfg.n0 = n0;
    let f = Arc::new(HFactors::build(&x, cfg).unwrap());
    let w = Mat::from_fn(n, m, |_, _| rng.normal());
    let pred = HPredictor::new(f.clone(), &w);
    (f, pred)
}

fn assert_close(got: &Mat, want: &Mat, tag: &str) {
    assert_eq!(got.shape(), want.shape(), "{tag}: shape");
    for i in 0..got.rows() {
        for j in 0..got.cols() {
            assert!(
                (got[(i, j)] - want[(i, j)]).abs() <= 1e-10 * (1.0 + want[(i, j)].abs()),
                "{tag} ({i},{j}): {} vs {}",
                got[(i, j)],
                want[(i, j)]
            );
        }
    }
}

/// Property (ISSUE 2 acceptance): for **every** shard depth 0..=leaf
/// level, the sharded scatter/gather path equals the unsharded predictor
/// to ≤ 1e-10 on multi-output weights, in request order — across
/// kernels, split rules and batch sizes (including batches that leave
/// some shards idle).
#[test]
fn sharded_matches_unsharded_at_every_depth() {
    let cases: &[(KernelKind, SplitRule, usize, u64)] = &[
        (Gaussian::new(0.6), SplitRule::RandomProjection, 3, 1),
        (Laplace::new(0.9), SplitRule::RandomProjection, 2, 2),
        (Gaussian::new(0.5), SplitRule::KMeans { k: 3, iters: 8 }, 3, 3),
    ];
    for &(kind, rule, m, seed) in cases {
        let (f, pred) = fitted(150, 4, 6, 8, m, kind, rule, seed);
        let mut rng = Rng::new(seed * 31 + 1);
        // Queries: random points plus training points (multi-query leaf
        // groups guaranteed).
        let q = Mat::from_fn(90, 4, |i, j| {
            if i < 45 {
                rng.uniform(-0.1, 1.1)
            } else {
                f.x[((i * 3) % 150, j)]
            }
        });
        // Scalar reference (the Algorithm-3 walk, one query at a time).
        let mut want = Mat::zeros(q.rows(), m);
        for i in 0..q.rows() {
            want.row_mut(i).copy_from_slice(&pred.predict(q.row(i)));
        }
        // Grouped unsharded batch path.
        assert_close(&pred.predict_batch(&q), &want, "unsharded grouped");

        for depth in 0..=f.tree.depth() {
            let sharded = ShardedPredictor::new(&pred, depth);
            assert_eq!(sharded.shards(), boundary_nodes(&f.tree, depth).len());
            let got = sharded.predict_batch(&q);
            assert_close(&got, &want, &format!("depth {depth} (rule {rule:?})"));
            // A batch of one (most shards idle) and an empty-ish small
            // batch keep request order too.
            let got1 = sharded.predict_batch(&q.row_range(0, 1));
            assert_close(&got1, &want.row_range(0, 1), &format!("depth {depth} single"));
        }
    }
}

/// Direct shard evaluation (no workers): the union of shards covers
/// every query, each shard agreeing with the unsharded predictor on the
/// queries routed to it.
#[test]
fn shard_local_evaluation_matches() {
    let (f, pred) = fitted(120, 3, 5, 6, 2, Gaussian::new(0.7), SplitRule::RandomProjection, 9);
    let mut rng = Rng::new(77);
    let q = Mat::from_fn(60, 3, |_, _| rng.uniform(0.0, 1.0));
    for depth in 0..=f.tree.depth() {
        let boundary = boundary_nodes(&f.tree, depth);
        let router = ShardRouter::new(&f.tree, &boundary);
        let shards = split_predictor(&pred, depth);
        for i in 0..q.rows() {
            let s = router.route(q.row(i));
            let got = shards[s].predict_batch(&q.row_range(i, i + 1));
            let want = pred.predict(q.row(i));
            for j in 0..2 {
                assert!(
                    (got[(0, j)] - want[j]).abs() <= 1e-10 * (1.0 + want[j].abs()),
                    "depth {depth} shard {s} query {i}: {} vs {}",
                    got[(0, j)],
                    want[j]
                );
            }
        }
    }
}

/// Router consistency (ISSUE 2 satellite): the shard-local walk lands in
/// exactly the leaf the unsharded tree walk finds, for every query and
/// every cut depth.
#[test]
fn router_and_shard_walk_find_the_unsharded_leaf() {
    for (rule, seed) in [
        (SplitRule::RandomProjection, 4u64),
        (SplitRule::KdTree, 5),
        (SplitRule::KMeans { k: 3, iters: 10 }, 6),
    ] {
        let (f, pred) = fitted(130, 4, 5, 7, 1, Gaussian::new(0.6), rule, seed);
        let mut rng = Rng::new(seed + 100);
        for depth in 0..=f.tree.depth() {
            let boundary = boundary_nodes(&f.tree, depth);
            let router = ShardRouter::new(&f.tree, &boundary);
            let shards = split_predictor(&pred, depth);
            for _ in 0..40 {
                let x: Vec<f64> = (0..4).map(|_| rng.uniform(-0.2, 1.2)).collect();
                let global_leaf = f.tree.route_leaf(&x);
                let s = router.route(&x);
                let local_leaf = shards[s].route_leaf(&x);
                let nd = &shards[s].nodes[local_leaf];
                let gnd = &f.tree.nodes[global_leaf];
                assert_eq!(
                    (nd.lo, nd.hi),
                    (gnd.lo, gnd.hi),
                    "rule {rule:?} depth {depth}: shard walk diverged from tree walk"
                );
            }
        }
    }
}

/// End-to-end: concurrent clients through the dynamic batcher in front
/// of the sharded predictor see single-replica results, under several
/// client thread counts; per-shard metrics account for every request.
#[test]
fn batcher_over_sharded_predictor_serves_identically() {
    let (f, pred) = fitted(140, 3, 6, 8, 2, Gaussian::new(0.5), SplitRule::RandomProjection, 12);
    let depth = 2.min(f.tree.depth());
    let mut rng = Rng::new(5);
    let q = Mat::from_fn(48, 3, |_, _| rng.uniform(0.0, 1.0));
    let want = pred.predict_batch(&q);

    for client_threads in [1usize, 2, 4, 8] {
        let sharded = ShardedPredictor::new(&pred, depth);
        let svc = Arc::new(PredictionService::start(
            Arc::new(sharded),
            BatchPolicy { max_batch: 16, max_wait: std::time::Duration::from_millis(4) },
        ));
        assert_eq!(svc.dim(), 3);
        let mut handles = Vec::new();
        for t in 0..client_threads {
            let svc = svc.clone();
            let rows: Vec<(usize, Vec<f64>)> = (0..q.rows())
                .filter(|i| i % client_threads == t)
                .map(|i| (i, q.row(i).to_vec()))
                .collect();
            let expect: Vec<Vec<f64>> =
                rows.iter().map(|(i, _)| want.row(*i).to_vec()).collect();
            handles.push(std::thread::spawn(move || {
                for ((_, feats), exp) in rows.into_iter().zip(expect) {
                    let got = svc.predict(feats).unwrap();
                    for j in 0..exp.len() {
                        assert!(
                            (got[j] - exp[j]).abs() <= 1e-10 * (1.0 + exp[j].abs()),
                            "{} vs {}",
                            got[j],
                            exp[j]
                        );
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = svc.snapshot();
        assert_eq!(snap.requests as usize, q.rows(), "clients={client_threads}");
        let shard_served: u64 = snap.shards.iter().map(|s| s.requests).sum();
        assert_eq!(shard_served as usize, q.rows(), "clients={client_threads}");
        assert!(snap.shards.iter().all(|s| s.queue_depth == 0));
        // Utilization telemetry: every shard reports a sane busy
        // fraction, idle shards report zero queue wait, and at least one
        // served shard measured the enqueue→dequeue hop.
        assert!(snap.shards.iter().all(|s| (0.0..=1.0).contains(&s.busy_frac)));
        assert!(snap.shards.iter().all(|s| s.batches > 0 || s.queue_wait_ns == 0.0));
        assert!(snap.shards.iter().any(|s| s.queue_wait_ns > 0.0), "clients={client_threads}");
    }
}
