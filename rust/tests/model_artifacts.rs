//! ISSUE 3 acceptance: every model kind round-trips through the `HCKM`
//! artifact format via `load_any` with identical predictions; corrupt
//! artifacts are rejected; and sharded-from-disk serving matches
//! in-process predictions at every cut depth.

use hck::coordinator::Predictor;
use hck::data::{spec_by_name, synthetic, Dataset};
use hck::hkernel::HConfig;
use hck::kernels::Gaussian;
use hck::learn::{EngineSpec, TrainConfig};
use hck::linalg::Mat;
use hck::model::{fit, load_any, Model, ModelKind, ModelSpec};
use hck::util::rng::Rng;

fn tmppath(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("hck_model_artifact_{tag}_{}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn regression_data() -> (Dataset, Dataset) {
    let spec = spec_by_name("cadata").unwrap();
    synthetic::generate(spec, 400, 80, 17)
}

fn assert_close(got: &Mat, want: &Mat, tol: f64, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    for i in 0..want.rows() {
        for j in 0..want.cols() {
            assert!(
                (got[(i, j)] - want[(i, j)]).abs() <= tol * (1.0 + want[(i, j)].abs()),
                "{what} ({i},{j}): {} vs {}",
                got[(i, j)],
                want[(i, j)]
            );
        }
    }
}

/// Every engine/model kind saves and reloads through `load_any` with
/// identical predictions (≤ 1e-12 — the payloads store the fitted state
/// verbatim and derived state is recomputed deterministically).
#[test]
fn every_model_kind_roundtrips_via_load_any() {
    let (train, test) = regression_data();
    let hcfg = |seed: u64| HConfig::new(Gaussian::new(0.5), 24).with_seed(seed);
    let specs: Vec<(&str, ModelSpec, ModelKind)> = vec![
        (
            "hier",
            ModelSpec::krr(
                TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 32 })
                    .with_seed(1),
            ),
            ModelKind::KrrHierarchical,
        ),
        (
            "nystrom",
            ModelSpec::krr(
                TrainConfig::new(Gaussian::new(0.5), EngineSpec::Nystrom { rank: 32 })
                    .with_seed(2),
            ),
            ModelKind::KrrNystrom,
        ),
        (
            "fourier",
            ModelSpec::krr(
                TrainConfig::new(Gaussian::new(0.5), EngineSpec::Fourier { rank: 32 })
                    .with_seed(3),
            ),
            ModelKind::KrrFourier,
        ),
        (
            "independent",
            ModelSpec::krr(
                TrainConfig::new(Gaussian::new(0.5), EngineSpec::Independent { n0: 32 })
                    .with_seed(4),
            ),
            ModelKind::KrrIndependent,
        ),
        (
            "exact",
            ModelSpec::krr(
                TrainConfig::new(Gaussian::new(0.5), EngineSpec::Exact).with_seed(5),
            ),
            ModelKind::KrrExact,
        ),
        ("gp", ModelSpec::gp(hcfg(6), 0.05), ModelKind::Gp),
        ("kpca", ModelSpec::kpca(hcfg(7), 4), ModelKind::Kpca),
    ];
    for (tag, spec, kind) in specs {
        let model: Box<dyn Model> = fit(&spec, &train).unwrap();
        let want = model.predict_batch(&test.x);
        let path = tmppath(tag);
        model.save(&path).unwrap();
        let loaded = load_any(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // The schema survives the trip.
        assert_eq!(loaded.schema().kind, kind, "{tag}");
        assert_eq!(loaded.schema().dim, train.d(), "{tag}");
        assert_eq!(loaded.schema().outputs, model.outputs(), "{tag}");
        assert_eq!(loaded.schema().task, train.task, "{tag}");
        // And so do the predictions.
        let got = loaded.predict_batch(&test.x);
        assert_close(&got, &want, 1e-12, tag);
    }
}

/// Multi-output (one-vs-all multiclass) weights round-trip too.
#[test]
fn multiclass_artifact_roundtrips() {
    let spec = spec_by_name("acoustic").unwrap();
    let (train, test) = synthetic::generate(spec, 300, 60, 23);
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.6), EngineSpec::Hierarchical { rank: 24 })
            .with_seed(9)
            .with_lambda(0.05),
    );
    let model = fit(&mspec, &train).unwrap();
    assert_eq!(model.outputs(), train.task.n_outputs());
    let want = model.predict_batch(&test.x);
    let path = tmppath("multiclass");
    model.save(&path).unwrap();
    let loaded = load_any(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.schema().task, train.task);
    assert_close(&loaded.predict_batch(&test.x), &want, 1e-12, "multiclass");
}

/// Preprocessing stats recorded in the spec ride through the artifact
/// and keep applying to raw queries.
#[test]
fn normalization_stats_ride_through_artifact() {
    let (train, _) = regression_data();
    let d = train.d();
    let ranges: Vec<(f64, f64)> = (0..d).map(|j| (0.0, 2.0 + j as f64)).collect();
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.5), EngineSpec::Nystrom { rank: 16 }).with_seed(3),
    )
    .with_normalization(ranges.clone());
    let model = fit(&mspec, &train).unwrap();
    let path = tmppath("norm");
    model.save(&path).unwrap();
    let loaded = load_any(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.schema().normalization.as_ref(), Some(&ranges));
    let raw = Mat::from_fn(3, d, |_, j| 1.0 + j as f64);
    assert_eq!(loaded.normalize(&raw), model.normalize(&raw));
}

/// Both serving paths preprocess raw queries with the artifact's
/// recorded normalization: the batcher-facing `Arc<dyn Model>` predictor
/// and the sharded-from-disk path must answer a RAW query exactly like
/// `Model::predict_batch` on explicitly normalized features.
#[test]
fn serving_paths_apply_recorded_normalization() {
    use std::sync::Arc;
    let (train, _) = regression_data();
    let d = train.d();
    let ranges: Vec<(f64, f64)> = (0..d).map(|j| (-1.0, 3.0 + j as f64)).collect();
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 16 })
            .with_seed(7),
    )
    .with_normalization(ranges.clone());
    let model = fit(&mspec, &train).unwrap();
    let mut rng = Rng::new(41);
    let raw = Mat::from_fn(20, d, |_, _| rng.uniform(-1.0, 4.0));
    let want = model.predict_batch(&model.normalize(&raw));

    // Unsharded serving path (what PredictionService::start_model runs).
    let arc: Arc<dyn Model> = Arc::from(model);
    let got = Predictor::predict_batch(&arc, &raw);
    assert_close(&got, &want, 1e-12, "arc predictor");

    // Sharded-from-disk serving path (norm.hckn rides with the shards).
    let pred = arc.hierarchical_predictor().unwrap();
    let dir = tmppath("normsharddir");
    hck::shard::save_shard_dir(pred, 1, &dir, Some(&ranges)).unwrap();
    let sharded = hck::shard::load_shard_dir(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let got = sharded.predict_batch(&raw);
    assert_close(&got, &want, 1e-10, "sharded with norm");
}

/// Header metadata (v2) round-trips through `save_meta` → `read_header`
/// without disturbing the payload, and a version-1 file (no metadata
/// section) still loads.
#[test]
fn header_metadata_roundtrips_and_v1_files_still_load() {
    let (train, test) = regression_data();
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 16 })
            .with_seed(3),
    );
    let model = fit(&mspec, &train).unwrap();
    let want = model.predict_batch(&test.x);

    // save_meta: metadata comes back via read_header, in order, and the
    // payload is untouched (identical predictions after load_any).
    let path = tmppath("meta");
    let meta = vec![
        ("phase.partition_secs".to_string(), "0.012".to_string()),
        ("trained_with".to_string(), "hck train".to_string()),
    ];
    model.save_meta(&path, &meta).unwrap();
    let header = hck::model::read_header(&path).unwrap();
    assert_eq!(header.version, hck::model::FORMAT_VERSION);
    assert_eq!(header.metadata, meta);
    assert_eq!(header.schema.dim, train.d());
    let loaded = load_any(&path).unwrap();
    assert_close(&loaded.predict_batch(&test.x), &want, 1e-12, "meta artifact");

    // Plain save records an empty metadata section.
    model.save(&path).unwrap();
    assert!(hck::model::read_header(&path).unwrap().metadata.is_empty());

    // v1 back-compat: for this schema (regression, no normalization) the
    // header is magic(4) + version(8) + five u64 schema words (40), so
    // the empty metadata count sits at bytes 52..60. Strip it and mark
    // the file version 1 — the loader must accept it.
    let mut bytes = std::fs::read(&path).unwrap();
    assert_eq!(&bytes[52..60], &[0u8; 8], "empty metadata count");
    bytes.drain(52..60);
    bytes[4] = 1;
    std::fs::write(&path, &bytes).unwrap();
    let header = hck::model::read_header(&path).unwrap();
    assert_eq!(header.version, 1);
    assert!(header.metadata.is_empty());
    let loaded = load_any(&path).unwrap();
    assert_close(&loaded.predict_batch(&test.x), &want, 1e-12, "v1 artifact");
    std::fs::remove_file(&path).ok();
}

/// Garbage, wrong-magic, truncated, and future-version files are all
/// rejected with an error — never a panic, never a silently wrong model.
#[test]
fn rejects_garbage_wrong_magic_truncation_and_version_mismatch() {
    // A valid artifact to corrupt.
    let (train, _) = regression_data();
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 16 })
            .with_seed(2),
    );
    let model = fit(&mspec, &train).unwrap();
    let path = tmppath("victim");
    model.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // Garbage / not an artifact at all.
    let p = tmppath("garbage");
    std::fs::write(&p, b"definitely not a model").unwrap();
    assert!(load_any(&p).is_err());
    // Wrong magic (another format's file must not parse as HCKM).
    let mut wrong = bytes.clone();
    wrong[..4].copy_from_slice(b"HCK1");
    std::fs::write(&p, &wrong).unwrap();
    assert!(load_any(&p).is_err());
    // Truncated at half.
    std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
    assert!(load_any(&p).is_err());
    // Version bump (bytes 4..12 are the little-endian format version).
    let mut future = bytes.clone();
    future[4] = future[4].wrapping_add(1);
    std::fs::write(&p, &future).unwrap();
    let err = load_any(&p).unwrap_err().to_string();
    assert!(err.contains("version"), "want a version error, got: {err}");
    std::fs::remove_file(&p).ok();

    // The original still loads after all that.
    assert!(load_any(&path).is_ok());
    std::fs::remove_file(&path).ok();
}

/// ISSUE 3 acceptance: shards saved to disk and served from a shard
/// directory match the in-process model to ≤ 1e-10 at **every** cut
/// depth, and GP models (hierarchical factors underneath) shard too.
#[test]
fn sharded_from_disk_matches_in_process_at_every_depth() {
    let (train, test) = regression_data();
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 12 })
            .with_seed(11),
    );
    let model = fit(&mspec, &train).unwrap();
    let want = model.predict_batch(&test.x);
    let pred = model.hierarchical_predictor().expect("hierarchical model");
    let tree_depth = pred.factors().tree.depth();
    assert!(tree_depth >= 2, "need a real tree for the depth sweep");
    for depth in 0..=tree_depth {
        let dir = tmppath(&format!("sharddir_{depth}"));
        let n = hck::shard::save_shard_dir(pred, depth, &dir, None).unwrap();
        let sharded = hck::shard::load_shard_dir(&dir).unwrap();
        assert_eq!(sharded.shards(), n, "depth {depth}");
        assert_eq!(sharded.dim(), train.d());
        let got = sharded.predict_batch(&test.x);
        assert_close(&got, &want, 1e-10, &format!("depth {depth}"));
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn gp_artifact_shards_from_disk() {
    let (train, test) = regression_data();
    let model = fit(
        &ModelSpec::gp(HConfig::new(Gaussian::new(0.5), 16).with_seed(13), 0.05),
        &train,
    )
    .unwrap();
    let want = model.predict_batch(&test.x);
    let path = tmppath("gp_artifact");
    model.save(&path).unwrap();
    let loaded = load_any(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let pred = loaded.hierarchical_predictor().expect("gp is hierarchical-backed");
    let dir = tmppath("gp_sharddir");
    hck::shard::save_shard_dir(pred, 1, &dir, None).unwrap();
    let sharded = hck::shard::load_shard_dir(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let got = sharded.predict_batch(&test.x);
    assert_close(&got, &want, 1e-10, "gp shards");
}

/// Re-sharding into the same directory replaces the previous cut's
/// files — stale shards from a deeper cut must not survive and poison
/// the loader.
#[test]
fn resharding_a_directory_replaces_stale_files() {
    let (train, test) = regression_data();
    let model = fit(
        &ModelSpec::krr(
            TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 12 })
                .with_seed(43),
        ),
        &train,
    )
    .unwrap();
    let pred = model.hierarchical_predictor().unwrap();
    let dir = tmppath("resharddir");
    let n2 = hck::shard::save_shard_dir(pred, 2, &dir, None).unwrap();
    let n1 = hck::shard::save_shard_dir(pred, 1, &dir, None).unwrap();
    assert!(n2 > n1, "depth 2 must cut more shards ({n2}) than depth 1 ({n1})");
    let sharded = hck::shard::load_shard_dir(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(sharded.shards(), n1);
    let got = sharded.predict_batch(&test.x);
    assert_close(&got, &model.predict_batch(&test.x), 1e-10, "reshard");
}

/// A broken shard directory is an error, not a panic or a misrouting.
#[test]
fn shard_dir_loading_rejects_inconsistent_directories() {
    let (train, _) = regression_data();
    let model = fit(
        &ModelSpec::krr(
            TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 12 })
                .with_seed(19),
        ),
        &train,
    )
    .unwrap();
    let pred = model.hierarchical_predictor().unwrap();
    let dir = tmppath("badsharddir");
    let n = hck::shard::save_shard_dir(pred, 1, &dir, None).unwrap();
    assert!(n >= 2);
    // Remove one shard file: count mismatch.
    let victim = format!("{dir}/shard0001.hcks");
    std::fs::remove_file(&victim).unwrap();
    assert!(hck::shard::load_shard_dir(&dir).is_err());
    // Remove the router: not servable at all.
    std::fs::remove_file(format!("{dir}/router.hckr")).unwrap();
    assert!(hck::shard::load_shard_dir(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();

    // An empty directory has neither router nor shards.
    let empty = tmppath("emptysharddir");
    std::fs::create_dir_all(&empty).unwrap();
    assert!(hck::shard::load_shard_dir(&empty).is_err());
    std::fs::remove_dir_all(&empty).ok();

    // And random queries on a healthy reload still route identically to
    // the unsharded walk (randomized spot check).
    let dir2 = tmppath("goodsharddir");
    hck::shard::save_shard_dir(pred, 2, &dir2, None).unwrap();
    let sharded = hck::shard::load_shard_dir(&dir2).unwrap();
    std::fs::remove_dir_all(&dir2).ok();
    let mut rng = Rng::new(29);
    let q = Mat::from_fn(40, train.d(), |_, _| rng.uniform(-0.2, 1.2));
    let got = sharded.predict_batch(&q);
    let want = model.predict_batch(&q);
    assert_close(&got, &want, 1e-10, "random queries");
}
