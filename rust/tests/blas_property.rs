//! Property tests pinning the packed BLAS-3 core against a naive
//! triple-loop oracle: every transpose pair, adversarial shapes (empty
//! dims, single rows/columns, sizes off every block multiple — MR=4,
//! NR=8, MC=64, KC=256, NC=1024), alpha/beta combinations, and the
//! bitwise-determinism contract `par_gemm == gemm` / `par_syrk == syrk`
//! for every thread count (the invariant the hierarchical solver's
//! parallel passes rely on).
//!
//! Since the microkernel grew runtime-dispatched SIMD backends
//! (`hck::linalg::simd`), the suite also pins the cross-backend
//! contract: every available SIMD backend agrees with the scalar
//! fallback ≤ 1e-13 elementwise (relative) on packed-plan shapes and
//! **bitwise** on small-plan shapes (where the microkernel never runs
//! and thus no FMA contraction happens), across all transpose pairs,
//! adversarial MR/NR remainder shapes, and alpha/beta cases — and
//! `par == serial` stays bitwise under each forced backend.

use hck::linalg::blas::uses_packed_plan;
use hck::linalg::simd::{self, Backend};
use hck::linalg::{gemm, par_gemm_with, par_syrk_with, syrk, Mat, Trans};
use hck::util::rng::Rng;
use std::sync::{Mutex, MutexGuard};

/// Serializes the tests that force the process-global SIMD backend
/// against the bitwise-comparison tests that assume the backend stays
/// put for their whole duration (test threads share the process).
static BACKEND_LOCK: Mutex<()> = Mutex::new(());

fn backend_guard() -> MutexGuard<'static, ()> {
    BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The SIMD (non-scalar) backends this machine can actually run.
fn simd_backends() -> Vec<Backend> {
    [Backend::Avx2, Backend::Neon].into_iter().filter(|b| b.available()).collect()
}

fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.normal())
}

/// Uniform(-1, 1) operands keep partial sums O(√k), so the cross-backend
/// FMA-contraction bound stays far inside the 1e-13 elementwise budget.
fn unimat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.uniform(-1.0, 1.0))
}

/// Cross-backend agreement check: bitwise where the packed plan (and
/// hence the SIMD microkernel) is not used, ≤ 1e-13 relative elementwise
/// where FMA contraction may differ from the scalar two-rounding tile.
fn assert_agree(be: Backend, got: &Mat, want: &Mat, packed: bool, ctx: &str) {
    if packed {
        for (g, w) in got.as_slice().iter().zip(want.as_slice()) {
            let tol = 1e-13 * (1.0 + w.abs());
            assert!((g - w).abs() <= tol, "{} vs scalar {ctx}: {g} vs {w}", be.name());
        }
    } else {
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "{} vs scalar must be bitwise on small plan {ctx}",
            be.name()
        );
    }
}

/// Entry (i, j) of op(A).
fn opv(a: &Mat, t: Trans, i: usize, j: usize) -> f64 {
    match t {
        Trans::No => a[(i, j)],
        Trans::Yes => a[(j, i)],
    }
}

/// Storage matrix whose op() has shape (rows, cols).
fn op_operand(rng: &mut Rng, t: Trans, rows: usize, cols: usize) -> Mat {
    match t {
        Trans::No => randmat(rng, rows, cols),
        Trans::Yes => randmat(rng, cols, rows),
    }
}

/// Naive triple-loop product op(A)·op(B) — the oracle.
fn oracle_mm(a: &Mat, ta: Trans, b: &Mat, tb: Trans, m: usize, k: usize, n: usize) -> Mat {
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for p in 0..k {
                s += opv(a, ta, i, p) * opv(b, tb, p, j);
            }
            c[(i, j)] = s;
        }
    }
    c
}

fn max_abs_diff(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let mut diff = a.clone();
    diff.axpy(-1.0, b);
    diff.max_abs()
}

/// Shapes chosen to cross every routing boundary: the small/packed plan
/// cut, partial MR/NR edge tiles, multiple MC row panels, a KC split
/// (k > 256) and an NC split (n > 1024).
const SHAPES: &[(usize, usize, usize)] = &[
    (0, 3, 2),
    (2, 0, 3),
    (3, 4, 0),
    (1, 1, 1),
    (1, 17, 9),
    (5, 1, 9),
    (13, 9, 17),
    (33, 8, 9),
    (65, 33, 70),
    (67, 257, 30),
    (12, 40, 1030),
    (130, 70, 65),
];

#[test]
fn gemm_matches_oracle_every_transpose_shape_and_scalar() {
    let scalars: &[(f64, f64)] = &[(1.0, 0.0), (0.0, 0.7), (-0.5, 1.0), (2.0, 0.3)];
    let mut rng = Rng::new(42);
    for &(m, k, n) in SHAPES {
        for &ta in &[Trans::No, Trans::Yes] {
            for &tb in &[Trans::No, Trans::Yes] {
                let a = op_operand(&mut rng, ta, m, k);
                let b = op_operand(&mut rng, tb, k, n);
                let prod = oracle_mm(&a, ta, &b, tb, m, k, n);
                for &(alpha, beta) in scalars {
                    let c0 = randmat(&mut rng, m, n);
                    let mut c = c0.clone();
                    gemm(alpha, &a, ta, &b, tb, beta, &mut c);
                    let mut want = prod.clone();
                    want.scale(alpha);
                    want.axpy(beta, &c0);
                    let diff = max_abs_diff(&c, &want);
                    let tol = 1e-11 * (k as f64 + 1.0);
                    assert!(
                        diff < tol,
                        "({m},{k},{n}) ta={ta:?} tb={tb:?} α={alpha} β={beta}: {diff}"
                    );
                }
            }
        }
    }
}

#[test]
fn par_gemm_is_bitwise_gemm_for_every_thread_count() {
    // Shapes straddling the parallel-volume gate and both plans; every
    // transpose pair on the largest one.
    let _g = backend_guard();
    let mut rng = Rng::new(7);
    let shapes: &[(usize, usize, usize)] =
        &[(5, 9, 40), (67, 257, 30), (130, 70, 65), (256, 32, 256)];
    for &(m, k, n) in shapes {
        for &ta in &[Trans::No, Trans::Yes] {
            for &tb in &[Trans::No, Trans::Yes] {
                let a = op_operand(&mut rng, ta, m, k);
                let b = op_operand(&mut rng, tb, k, n);
                let c0 = randmat(&mut rng, m, n);
                let mut want = c0.clone();
                gemm(1.3, &a, ta, &b, tb, 0.4, &mut want);
                for threads in [1usize, 2, 3, 8] {
                    let mut c = c0.clone();
                    par_gemm_with(threads, 1.3, &a, ta, &b, tb, 0.4, &mut c);
                    assert_eq!(
                        c.as_slice(),
                        want.as_slice(),
                        "threads={threads} shape=({m},{k},{n}) ta={ta:?} tb={tb:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn syrk_matches_oracle_both_transposes() {
    let scalars: &[(f64, f64)] = &[(1.0, 0.0), (0.5, 1.0), (0.0, 0.3), (2.0, 0.0)];
    let mut rng = Rng::new(11);
    let shapes: &[(usize, usize)] =
        &[(0, 4), (1, 1), (3, 0), (7, 3), (40, 70), (70, 40), (130, 33)];
    for &(m, k) in shapes {
        for &ta in &[Trans::No, Trans::Yes] {
            let a = op_operand(&mut rng, ta, m, k);
            let prod = {
                // op(A) · op(A)ᵀ via the gemm oracle.
                let tb = match ta {
                    Trans::No => Trans::Yes,
                    Trans::Yes => Trans::No,
                };
                oracle_mm(&a, ta, &a, tb, m, k, m)
            };
            for &(alpha, beta) in scalars {
                let c0 = randmat(&mut rng, m, m);
                let mut c = c0.clone();
                syrk(alpha, &a, ta, beta, &mut c);
                assert!(c.is_symmetric(0.0), "syrk output must be exactly symmetric");
                // syrk semantics: upper triangle = α·prod + β·C0's upper,
                // lower mirrored from it.
                for i in 0..m {
                    for j in i..m {
                        let want = alpha * prod[(i, j)] + beta * c0[(i, j)];
                        let diff = (c[(i, j)] - want).abs();
                        let tol = 1e-11 * (k as f64 + 1.0);
                        assert!(
                            diff < tol,
                            "({m},{k}) ta={ta:?} α={alpha} β={beta} at ({i},{j}): {diff}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn par_syrk_is_bitwise_syrk_for_every_thread_count() {
    let _g = backend_guard();
    let mut rng = Rng::new(13);
    for &(m, k, ta) in &[(130usize, 50usize, Trans::No), (70, 200, Trans::Yes)] {
        let a = op_operand(&mut rng, ta, m, k);
        let c0 = randmat(&mut rng, m, m);
        let mut want = c0.clone();
        syrk(0.8, &a, ta, 0.25, &mut want);
        for threads in [1usize, 2, 3, 8] {
            let mut c = c0.clone();
            par_syrk_with(threads, 0.8, &a, ta, 0.25, &mut c);
            assert_eq!(c.as_slice(), want.as_slice(), "threads={threads} (m={m}, k={k})");
        }
    }
}

#[test]
fn gemm_simd_agrees_with_scalar_across_remainder_shapes() {
    // m, n, k ∈ {1, 3, 5, 7, 9, 63, 65} hits every MR=4 / NR=8 remainder
    // class, both sides of the small/packed plan cut, and an MC=64 row
    // panel split — under every transpose pair and two alpha/beta cases.
    let _g = backend_guard();
    let initial = simd::backend();
    let simd_set = simd_backends();
    let dims: &[usize] = &[1, 3, 5, 7, 9, 63, 65];
    let scalars: &[(f64, f64)] = &[(1.0, 0.0), (-0.5, 0.7)];
    let mut rng = Rng::new(2024);
    for &m in dims {
        for &k in dims {
            for &n in dims {
                for &ta in &[Trans::No, Trans::Yes] {
                    for &tb in &[Trans::No, Trans::Yes] {
                        let a = match ta {
                            Trans::No => unimat(&mut rng, m, k),
                            Trans::Yes => unimat(&mut rng, k, m),
                        };
                        let b = match tb {
                            Trans::No => unimat(&mut rng, k, n),
                            Trans::Yes => unimat(&mut rng, n, k),
                        };
                        let c0 = unimat(&mut rng, m, n);
                        for &(alpha, beta) in scalars {
                            simd::force_backend(Backend::Scalar).unwrap();
                            let mut want = c0.clone();
                            gemm(alpha, &a, ta, &b, tb, beta, &mut want);
                            let ctx = format!("({m},{k},{n}) {ta:?}/{tb:?} α={alpha} β={beta}");
                            for &be in &simd_set {
                                simd::force_backend(be).unwrap();
                                let mut c = c0.clone();
                                gemm(alpha, &a, ta, &b, tb, beta, &mut c);
                                assert_agree(be, &c, &want, uses_packed_plan(m, k, n), &ctx);
                            }
                        }
                    }
                }
            }
        }
    }
    simd::force_backend(initial).unwrap();
}

#[test]
fn syrk_simd_agrees_with_scalar() {
    let _g = backend_guard();
    let initial = simd::backend();
    let mut rng = Rng::new(57);
    for &(m, k) in &[(7usize, 3usize), (63, 65), (65, 63), (130, 33)] {
        for &ta in &[Trans::No, Trans::Yes] {
            let a = match ta {
                Trans::No => unimat(&mut rng, m, k),
                Trans::Yes => unimat(&mut rng, k, m),
            };
            let c0 = unimat(&mut rng, m, m);
            simd::force_backend(Backend::Scalar).unwrap();
            let mut want = c0.clone();
            syrk(0.75, &a, ta, 0.5, &mut want);
            let ctx = format!("syrk ({m},{k}) ta={ta:?}");
            for &be in &simd_backends() {
                simd::force_backend(be).unwrap();
                let mut c = c0.clone();
                syrk(0.75, &a, ta, 0.5, &mut c);
                assert!(c.is_symmetric(0.0), "syrk stays exactly symmetric under {}", be.name());
                // syrk's plan gate is (m, k, m) — same cut as gemm's.
                assert_agree(be, &c, &want, uses_packed_plan(m, k, m), &ctx);
            }
        }
    }
    simd::force_backend(initial).unwrap();
}

#[test]
fn par_matches_serial_bitwise_under_each_forced_backend() {
    // The determinism contract must hold per backend: whatever tile the
    // dispatcher picked, the row-panel parallel path reuses it and stays
    // bitwise identical to the serial path.
    let _g = backend_guard();
    let initial = simd::backend();
    let mut backends = vec![Backend::Scalar];
    backends.extend(simd_backends());
    let mut rng = Rng::new(31);
    for &be in &backends {
        simd::force_backend(be).unwrap();
        for &(m, k, n) in &[(5usize, 9usize, 40usize), (130, 70, 65), (256, 32, 256)] {
            let a = op_operand(&mut rng, Trans::No, m, k);
            let b = op_operand(&mut rng, Trans::Yes, k, n);
            let c0 = randmat(&mut rng, m, n);
            let mut want = c0.clone();
            gemm(1.3, &a, Trans::No, &b, Trans::Yes, 0.4, &mut want);
            for threads in [2usize, 3, 8] {
                let mut c = c0.clone();
                par_gemm_with(threads, 1.3, &a, Trans::No, &b, Trans::Yes, 0.4, &mut c);
                assert_eq!(
                    c.as_slice(),
                    want.as_slice(),
                    "backend={} threads={threads} gemm ({m},{k},{n})",
                    be.name()
                );
            }
        }
        for &(m, k, ta) in &[(130usize, 50usize, Trans::No), (70, 200, Trans::Yes)] {
            let a = op_operand(&mut rng, ta, m, k);
            let c0 = randmat(&mut rng, m, m);
            let mut want = c0.clone();
            syrk(0.8, &a, ta, 0.25, &mut want);
            for threads in [2usize, 3, 8] {
                let mut c = c0.clone();
                par_syrk_with(threads, 0.8, &a, ta, 0.25, &mut c);
                assert_eq!(
                    c.as_slice(),
                    want.as_slice(),
                    "backend={} threads={threads} syrk (m={m}, k={k})",
                    be.name()
                );
            }
        }
    }
    simd::force_backend(initial).unwrap();
}
