//! Property tests pinning the packed BLAS-3 core against a naive
//! triple-loop oracle: every transpose pair, adversarial shapes (empty
//! dims, single rows/columns, sizes off every block multiple — MR=4,
//! NR=8, MC=64, KC=256, NC=1024), alpha/beta combinations, and the
//! bitwise-determinism contract `par_gemm == gemm` / `par_syrk == syrk`
//! for every thread count (the invariant the hierarchical solver's
//! parallel passes rely on).

use hck::linalg::{gemm, par_gemm_with, par_syrk_with, syrk, Mat, Trans};
use hck::util::rng::Rng;

fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.normal())
}

/// Entry (i, j) of op(A).
fn opv(a: &Mat, t: Trans, i: usize, j: usize) -> f64 {
    match t {
        Trans::No => a[(i, j)],
        Trans::Yes => a[(j, i)],
    }
}

/// Storage matrix whose op() has shape (rows, cols).
fn op_operand(rng: &mut Rng, t: Trans, rows: usize, cols: usize) -> Mat {
    match t {
        Trans::No => randmat(rng, rows, cols),
        Trans::Yes => randmat(rng, cols, rows),
    }
}

/// Naive triple-loop product op(A)·op(B) — the oracle.
fn oracle_mm(a: &Mat, ta: Trans, b: &Mat, tb: Trans, m: usize, k: usize, n: usize) -> Mat {
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for p in 0..k {
                s += opv(a, ta, i, p) * opv(b, tb, p, j);
            }
            c[(i, j)] = s;
        }
    }
    c
}

fn max_abs_diff(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let mut diff = a.clone();
    diff.axpy(-1.0, b);
    diff.max_abs()
}

/// Shapes chosen to cross every routing boundary: the small/packed plan
/// cut, partial MR/NR edge tiles, multiple MC row panels, a KC split
/// (k > 256) and an NC split (n > 1024).
const SHAPES: &[(usize, usize, usize)] = &[
    (0, 3, 2),
    (2, 0, 3),
    (3, 4, 0),
    (1, 1, 1),
    (1, 17, 9),
    (5, 1, 9),
    (13, 9, 17),
    (33, 8, 9),
    (65, 33, 70),
    (67, 257, 30),
    (12, 40, 1030),
    (130, 70, 65),
];

#[test]
fn gemm_matches_oracle_every_transpose_shape_and_scalar() {
    let scalars: &[(f64, f64)] = &[(1.0, 0.0), (0.0, 0.7), (-0.5, 1.0), (2.0, 0.3)];
    let mut rng = Rng::new(42);
    for &(m, k, n) in SHAPES {
        for &ta in &[Trans::No, Trans::Yes] {
            for &tb in &[Trans::No, Trans::Yes] {
                let a = op_operand(&mut rng, ta, m, k);
                let b = op_operand(&mut rng, tb, k, n);
                let prod = oracle_mm(&a, ta, &b, tb, m, k, n);
                for &(alpha, beta) in scalars {
                    let c0 = randmat(&mut rng, m, n);
                    let mut c = c0.clone();
                    gemm(alpha, &a, ta, &b, tb, beta, &mut c);
                    let mut want = prod.clone();
                    want.scale(alpha);
                    want.axpy(beta, &c0);
                    let diff = max_abs_diff(&c, &want);
                    let tol = 1e-11 * (k as f64 + 1.0);
                    assert!(
                        diff < tol,
                        "({m},{k},{n}) ta={ta:?} tb={tb:?} α={alpha} β={beta}: {diff}"
                    );
                }
            }
        }
    }
}

#[test]
fn par_gemm_is_bitwise_gemm_for_every_thread_count() {
    // Shapes straddling the parallel-volume gate and both plans; every
    // transpose pair on the largest one.
    let mut rng = Rng::new(7);
    let shapes: &[(usize, usize, usize)] =
        &[(5, 9, 40), (67, 257, 30), (130, 70, 65), (256, 32, 256)];
    for &(m, k, n) in shapes {
        for &ta in &[Trans::No, Trans::Yes] {
            for &tb in &[Trans::No, Trans::Yes] {
                let a = op_operand(&mut rng, ta, m, k);
                let b = op_operand(&mut rng, tb, k, n);
                let c0 = randmat(&mut rng, m, n);
                let mut want = c0.clone();
                gemm(1.3, &a, ta, &b, tb, 0.4, &mut want);
                for threads in [1usize, 2, 3, 8] {
                    let mut c = c0.clone();
                    par_gemm_with(threads, 1.3, &a, ta, &b, tb, 0.4, &mut c);
                    assert_eq!(
                        c.as_slice(),
                        want.as_slice(),
                        "threads={threads} shape=({m},{k},{n}) ta={ta:?} tb={tb:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn syrk_matches_oracle_both_transposes() {
    let scalars: &[(f64, f64)] = &[(1.0, 0.0), (0.5, 1.0), (0.0, 0.3), (2.0, 0.0)];
    let mut rng = Rng::new(11);
    let shapes: &[(usize, usize)] =
        &[(0, 4), (1, 1), (3, 0), (7, 3), (40, 70), (70, 40), (130, 33)];
    for &(m, k) in shapes {
        for &ta in &[Trans::No, Trans::Yes] {
            let a = op_operand(&mut rng, ta, m, k);
            let prod = {
                // op(A) · op(A)ᵀ via the gemm oracle.
                let tb = match ta {
                    Trans::No => Trans::Yes,
                    Trans::Yes => Trans::No,
                };
                oracle_mm(&a, ta, &a, tb, m, k, m)
            };
            for &(alpha, beta) in scalars {
                let c0 = randmat(&mut rng, m, m);
                let mut c = c0.clone();
                syrk(alpha, &a, ta, beta, &mut c);
                assert!(c.is_symmetric(0.0), "syrk output must be exactly symmetric");
                // syrk semantics: upper triangle = α·prod + β·C0's upper,
                // lower mirrored from it.
                for i in 0..m {
                    for j in i..m {
                        let want = alpha * prod[(i, j)] + beta * c0[(i, j)];
                        let diff = (c[(i, j)] - want).abs();
                        let tol = 1e-11 * (k as f64 + 1.0);
                        assert!(
                            diff < tol,
                            "({m},{k}) ta={ta:?} α={alpha} β={beta} at ({i},{j}): {diff}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn par_syrk_is_bitwise_syrk_for_every_thread_count() {
    let mut rng = Rng::new(13);
    for &(m, k, ta) in &[(130usize, 50usize, Trans::No), (70, 200, Trans::Yes)] {
        let a = op_operand(&mut rng, ta, m, k);
        let c0 = randmat(&mut rng, m, m);
        let mut want = c0.clone();
        syrk(0.8, &a, ta, 0.25, &mut want);
        for threads in [1usize, 2, 3, 8] {
            let mut c = c0.clone();
            par_syrk_with(threads, 0.8, &a, ta, 0.25, &mut c);
            assert_eq!(c.as_slice(), want.as_slice(), "threads={threads} (m={m}, k={k})");
        }
    }
}
