//! Integration tests across modules: PJRT runtime vs native numerics,
//! end-to-end training on synthetic Table-1 analogues, hierarchical
//! factors built through the PJRT evaluator, and coordinator serving.
//!
//! Requires `make artifacts` for the PJRT cases (they are skipped with a
//! note when the artifact directory is absent, so `cargo test` stays
//! green in a fresh checkout).

use hck::data::{spec_by_name, synthetic};
use hck::hkernel::{HConfig, HFactors, HSolver};
use hck::kernels::{kernel_cross, Gaussian, Imq, Laplace};
use hck::learn::{EngineSpec, KrrModel, TrainConfig};
use hck::linalg::Mat;
use hck::runtime::{PjrtBlockEvaluator, PjrtEngine};
use hck::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    // cargo test runs with cwd = crate root.
    let p = std::path::Path::new("artifacts/manifest.json");
    if p.exists() {
        Some(p.parent().unwrap().to_path_buf())
    } else {
        None
    }
}

#[test]
fn pjrt_kernel_block_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let engine = PjrtEngine::load(dir).expect("engine");
    let mut rng = Rng::new(1);
    // Deliberately ragged shapes to exercise padding + tiling.
    for (m, n, d) in [(130usize, 70usize, 5usize), (128, 128, 8), (33, 257, 21), (7, 3, 64)] {
        let x = Mat::from_fn(m, d, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0));
        for kind in [Gaussian::new(0.6), Laplace::new(0.9), Imq::new(0.8)] {
            let got = engine.kernel_block(kind, &x, &y).expect("pjrt exec");
            let want = kernel_cross(kind, &x, &y);
            let mut diff = got.clone();
            diff.axpy(-1.0, &want);
            // f32 path vs f64 native.
            assert!(
                diff.max_abs() < 5e-6,
                "{kind:?} ({m},{n},{d}): max abs diff {}",
                diff.max_abs()
            );
        }
    }
    let stats = engine.stats.lock().unwrap().clone();
    assert!(stats.tiles_executed > 0);
}

#[test]
fn hierarchical_factors_via_pjrt_evaluator() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let engine = std::sync::Arc::new(PjrtEngine::load(dir).expect("engine"));
    let eval = PjrtBlockEvaluator::new(engine);
    let mut rng = Rng::new(2);
    let x = Mat::from_fn(96, 8, |_, _| rng.uniform(0.0, 1.0));
    let mut cfg = HConfig::new(Gaussian::new(0.5), 12).with_seed(3);
    cfg.n0 = 12;
    let mut rng2 = Rng::new(cfg.seed);
    let tree = hck::partition::PartitionTree::build(&x, cfg.n0, cfg.rule, &mut rng2);
    let f_pjrt =
        HFactors::build_on_tree(&x, cfg.clone(), tree, &mut rng2, &eval).expect("pjrt build");
    let f_native = {
        let mut rng3 = Rng::new(cfg.seed);
        let tree = hck::partition::PartitionTree::build(&x, cfg.n0, cfg.rule, &mut rng3);
        HFactors::build_on_tree(&x, cfg, tree, &mut rng3, &hck::kernels::NativeEvaluator)
            .expect("native build")
    };
    // Same seeds -> same tree/landmarks; factors agree to f32 precision.
    let k1 = hck::hkernel::densify::densify(&f_pjrt);
    let k2 = hck::hkernel::densify::densify(&f_native);
    let mut diff = k1.clone();
    diff.axpy(-1.0, &k2);
    assert!(diff.max_abs() < 1e-4, "max diff {}", diff.max_abs());
    // And the whole pipeline still solves.
    let solver = HSolver::factor(&f_pjrt, 0.05).expect("solver");
    let y: Vec<f64> = (0..96).map(|i| (i as f64 * 0.1).sin()).collect();
    let w = solver.solve_original(&y);
    assert!(w.iter().all(|v| v.is_finite()));
}

/// Property (ISSUE 1 acceptance): the parallel matvec with T threads
/// matches both the densified reference and the 1-thread result to
/// ≤ 1e-10 across random trees, ranks, leaf sizes and split rules.
#[test]
fn parallel_matvec_matches_dense_reference_and_single_thread() {
    use hck::hkernel::hmatvec_with_threads;
    use hck::partition::SplitRule;
    let cases: &[(usize, usize, usize, SplitRule, u64)] = &[
        (120, 8, 8, SplitRule::RandomProjection, 1),
        (97, 6, 13, SplitRule::RandomProjection, 2),
        (150, 12, 5, SplitRule::KdTree, 3),
        (132, 9, 11, SplitRule::KMeans { k: 3, iters: 10 }, 4),
    ];
    for &(n, r, n0, rule, seed) in cases {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 5, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(Gaussian::new(0.6), r)
            .with_seed(seed * 11 + 1)
            .with_rule(rule);
        cfg.n0 = n0;
        let f = HFactors::build(&x, cfg).unwrap();
        let k = hck::hkernel::densify::densify(&f);
        for trial in 0..2 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut dense = vec![0.0; n];
            hck::linalg::gemv(1.0, &k, hck::linalg::Trans::No, &b, 0.0, &mut dense);
            let y1 = hmatvec_with_threads(&f, &b, 1);
            for threads in [1usize, 2, 3, 4, 8] {
                let yt = hmatvec_with_threads(&f, &b, threads);
                for i in 0..n {
                    let scale = 1.0 + dense[i].abs();
                    assert!(
                        (yt[i] - dense[i]).abs() <= 1e-10 * scale,
                        "vs dense: n={n} r={r} rule={rule:?} trial={trial} threads={threads} \
                         i={i}: {} vs {}",
                        yt[i],
                        dense[i]
                    );
                    assert!(
                        (yt[i] - y1[i]).abs() <= 1e-10 * scale,
                        "vs 1 thread: n={n} threads={threads} i={i}"
                    );
                }
                // The schedule is single-writer with ordered application,
                // so the match is in fact bitwise.
                assert_eq!(yt, y1, "threads={threads}");
            }
        }
    }
}

/// The parallel leaf factorization must not change the solver: factor,
/// solve and logdet agree with the dense reference whatever HCK_THREADS
/// happens to be (the leaf states are computed independently and the
/// log-det is reduced in post-order).
#[test]
fn parallel_solver_factor_matches_dense() {
    let mut rng = Rng::new(7);
    let n = 140;
    let x = Mat::from_fn(n, 4, |_, _| rng.uniform(0.0, 1.0));
    let mut cfg = HConfig::new(Gaussian::new(0.5), 10).with_seed(9);
    cfg.n0 = 10;
    let f = HFactors::build(&x, cfg).unwrap();
    let lambda = 0.05;
    let solver = HSolver::factor(&f, lambda).unwrap();
    let mut k = hck::hkernel::densify::densify(&f);
    k.add_diag(lambda);
    let chol = hck::linalg::Cholesky::new_jittered(&k, 10).unwrap();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let got = solver.solve(&y);
    let want = chol.solve(&y);
    for i in 0..n {
        assert!(
            (got[i] - want[i]).abs() <= 1e-8 * (1.0 + want[i].abs()),
            "solve i={i}: {} vs {}",
            got[i],
            want[i]
        );
    }
    assert!(
        (solver.logdet() - chol.logdet()).abs() < 1e-7 * (1.0 + chol.logdet().abs()),
        "logdet {} vs {}",
        solver.logdet(),
        chol.logdet()
    );
}

#[test]
fn end_to_end_training_all_table1_sets() {
    // Scaled-down: every Table-1 analogue trains and beats the trivial
    // baseline with the hierarchical engine.
    for name in ["cadata", "ijcnn1", "acoustic"] {
        let spec = spec_by_name(name).unwrap();
        let (train, test) = synthetic::generate(spec, 500, 120, 9);
        let cfg = TrainConfig::new(
            Gaussian::new(0.5),
            EngineSpec::Hierarchical { rank: 64 },
        )
        .with_seed(4);
        let model = KrrModel::fit_dataset(&cfg, &train).expect("fit");
        let metric = model.evaluate(&test);
        match train.task {
            hck::data::Task::Regression => {
                assert!(metric < 0.9, "{name}: rel err {metric}")
            }
            _ => assert!(metric > 0.55, "{name}: acc {metric}"),
        }
    }
}

#[test]
fn coordinator_serves_trained_model() {
    use hck::coordinator::{BatchPolicy, PredictionService};
    let spec = spec_by_name("cadata").unwrap();
    let (train, test) = synthetic::generate(spec, 400, 50, 13);
    let cfg = TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 50 })
        .with_seed(5);
    let model = KrrModel::fit_dataset(&cfg, &train).expect("fit");
    // Reference predictions (direct path).
    let direct = model.predict(&test.x);
    let svc = std::sync::Arc::new(PredictionService::start(
        std::sync::Arc::new(model),
        BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(5) },
    ));
    // Concurrent clients through the batcher must agree with the direct path.
    let mut handles = Vec::new();
    for i in 0..test.n() {
        let svc = svc.clone();
        let feats = test.x.row(i).to_vec();
        let want = direct[(i, 0)];
        handles.push(std::thread::spawn(move || {
            let got = svc.predict(feats).unwrap()[0];
            assert!((got - want).abs() < 1e-10, "{got} vs {want}");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.requests as usize, test.n());
}

#[test]
fn solver_scales_linearly_in_n() {
    // Weak O(n r^2) sanity: doubling n should not quadruple factor time.
    // (Generous 3.5x bound to stay robust on a noisy CI machine.)
    let spec = spec_by_name("cadata").unwrap();
    let time_for = |n: usize| {
        let (train, _) = synthetic::generate(spec, n, 10, 17);
        let mut cfg = HConfig::new(Gaussian::new(0.5), 32).with_seed(3);
        cfg.n0 = 32;
        let f = HFactors::build(&train.x, cfg).unwrap();
        let t = std::time::Instant::now();
        let solver = HSolver::factor(&f, 0.01).unwrap();
        let y: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let _ = solver.solve(&f.to_tree_order(&y));
        t.elapsed().as_secs_f64()
    };
    // Warm up and take medians of 3.
    let med = |n: usize| {
        let mut ts: Vec<f64> = (0..3).map(|_| time_for(n)).collect();
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ts[1]
    };
    let t1 = med(2000);
    let t2 = med(4000);
    assert!(
        t2 / t1 < 3.5,
        "factor+solve time ratio {:.2} suggests super-linear scaling",
        t2 / t1
    );
}
