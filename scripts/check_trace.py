#!/usr/bin/env python3
"""Validate a Chrome-trace JSON file produced by `--trace` / HCK_TRACE.

Checks the export loads in Perfetto / chrome://tracing: the file is a
JSON array (or an object with a `traceEvents` array), every event
carries name/ph/pid/tid, metadata events (ph == "M") are thread_name
records with a string args.name, complete events (ph == "X") carry
numeric ts/dur with non-decreasing ts (the exporter emits them sorted by
start time), and args — when present — are objects.

`--require a,b,c` additionally fails unless every listed span name
appears at least once among the X events, so CI can pin the
instrumentation points (e.g. coord.queue_wait) that a refactor must not
silently drop.

`--require-request-ids` fails unless at least one X event carries a
numeric args.request_id — the end-to-end check that request ids survive
from the protocol layer into the trace.

`--known-spans FILE` reads the span-name registry exported by
`hck-lint --emit-spans` (one name per line, `#` comments allowed) and
fails if any X event — or any `--require`d name — is outside it. The
registry lives in `rust/src/obs/registry.rs` and the lint keeps it in
lockstep with the instrumentation call sites, so this closes the loop:
names are checked statically at the call site and dynamically in the
trace against the same table.

Usage: check_trace.py TRACE.json [--require a,b,...]
       [--require-request-ids] [--known-spans FILE]
"""

import json
import sys


def fail(msg):
    print(f"check_trace: FAIL: {msg}")
    return 1


def main(argv):
    args = []
    required = []
    want_request_ids = False
    known_spans_path = None
    it = iter(argv)
    for a in it:
        if a == "--require":
            required = [s for s in next(it, "").split(",") if s]
        elif a.startswith("--require="):
            required = [s for s in a.split("=", 1)[1].split(",") if s]
        elif a == "--require-request-ids":
            want_request_ids = True
        elif a == "--known-spans":
            known_spans_path = next(it, None)
        elif a.startswith("--known-spans="):
            known_spans_path = a.split("=", 1)[1]
        else:
            args.append(a)
    if len(args) != 1 or known_spans_path == "":
        print(__doc__)
        return 2
    path = args[0]

    known = None
    if known_spans_path is not None:
        try:
            with open(known_spans_path, encoding="utf-8") as fh:
                known = {
                    line.strip()
                    for line in fh
                    if line.strip() and not line.lstrip().startswith("#")
                }
        except OSError as exc:
            return fail(f"cannot read known-spans file ({exc})")
        if not known:
            return fail(f"{known_spans_path} lists no span names")
        rogue_required = [name for name in required if name not in known]
        if rogue_required:
            return fail(
                f"--require names outside the registry: {', '.join(rogue_required)}"
            )

    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as exc:
        return fail(f"{path} is not readable JSON ({exc})")

    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return fail("object form must carry a traceEvents array")
    elif isinstance(doc, list):
        events = doc
    else:
        return fail(f"top level must be an array or object, got {type(doc).__name__}")

    spans = 0
    meta = 0
    last_ts = None
    seen_names = set()
    request_ids = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            return fail(f"event {i} is not an object")
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                return fail(f"event {i} is missing required field '{field}'")
        ph = ev["ph"]
        arg_obj = ev.get("args")
        if arg_obj is not None and not isinstance(arg_obj, dict):
            return fail(f"event {i} has non-object args")
        if ph == "M":
            meta += 1
            if ev["name"] != "thread_name":
                return fail(f"metadata event {i} is not a thread_name record")
            if not isinstance((arg_obj or {}).get("name"), str):
                return fail(f"thread_name event {i} lacks a string args.name")
            continue
        if ph != "X":
            return fail(f"event {i} has unexpected phase '{ph}' (exporter emits M and X)")
        spans += 1
        for field in ("ts", "dur"):
            if not isinstance(ev.get(field), (int, float)):
                return fail(f"span event {i} has non-numeric '{field}'")
        if last_ts is not None and ev["ts"] < last_ts:
            return fail(f"span event {i} breaks ts ordering ({ev['ts']} < {last_ts})")
        last_ts = ev["ts"]
        seen_names.add(ev["name"])
        rid = (arg_obj or {}).get("request_id")
        if isinstance(rid, (int, float)):
            request_ids.add(rid)

    if known is not None:
        rogue = sorted(seen_names - known)
        if rogue:
            return fail(
                f"trace records span names outside the registry: {', '.join(rogue)} "
                f"(regenerate with `hck-lint --emit-spans` or register them)"
            )

    missing = [name for name in required if name not in seen_names]
    if missing:
        return fail(
            f"required span names absent: {', '.join(missing)} "
            f"(have: {', '.join(sorted(seen_names)) or 'none'})"
        )
    if want_request_ids and not request_ids:
        return fail("no span carries args.request_id")

    rid_note = f", {len(request_ids)} distinct request ids" if request_ids else ""
    print(
        f"check_trace: ok — {spans} spans across {len(seen_names)} names, "
        f"{meta} thread records{rid_note}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
