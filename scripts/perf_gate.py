#!/usr/bin/env python3
"""Perf gate over BENCH_*.json telemetry.

Compares the current benchmark report against a baseline from the
previous CI run and fails (exit 1) when any matching op regresses by
more than the threshold. Rows are matched on their identity keys
(op, n, r, threads, batch, shards); the measured value is ns_per_op or
ns_per_query. Skips gracefully (exit 0) when the baseline is missing or
unreadable — the first run on a fresh repository has no history.

Usage: perf_gate.py BASELINE.json CURRENT.json [--threshold 0.25]
"""

import json
import sys

KEY_FIELDS = ("op", "n", "r", "threads", "batch", "shards")
VALUE_FIELDS = ("ns_per_op", "ns_per_query")


def load_rows(path):
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    rows = {}
    for row in doc.get("rows", []):
        key = tuple(row.get(k) for k in KEY_FIELDS)
        value = next(
            (row[v] for v in VALUE_FIELDS if isinstance(row.get(v), (int, float))),
            None,
        )
        if value is not None and value > 0:
            rows[key] = value
    return rows


def main(argv):
    args = []
    threshold = 0.25
    it = iter(argv)
    for a in it:
        if a == "--threshold":
            threshold = float(next(it, "0.25"))
        elif a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__)
        return 2
    baseline_path, current_path = args

    try:
        baseline = load_rows(baseline_path)
    except (OSError, ValueError) as exc:
        print(f"perf gate: no usable baseline ({exc}); skipping")
        return 0
    try:
        current = load_rows(current_path)
    except (OSError, ValueError) as exc:
        print(f"perf gate: current report unreadable ({exc})")
        return 1
    if not baseline:
        print("perf gate: baseline has no comparable rows; skipping")
        return 0

    failures = []
    compared = 0
    for key, base in sorted(baseline.items(), key=str):
        cur = current.get(key)
        if cur is None:
            continue  # op removed or renamed: not a regression
        compared += 1
        ratio = cur / base
        label = " ".join(f"{k}={v}" for k, v in zip(KEY_FIELDS, key) if v is not None)
        status = "FAIL" if ratio > 1.0 + threshold else "ok"
        print(f"  [{status}] {label}: {base:.0f} -> {cur:.0f} ns ({ratio - 1.0:+.1%})")
        if ratio > 1.0 + threshold:
            failures.append(label)

    if compared == 0:
        print("perf gate: no overlapping rows between baseline and current; skipping")
        return 0
    if failures:
        print(
            f"perf gate: {len(failures)}/{compared} ops regressed "
            f">{threshold:.0%}: {', '.join(failures)}"
        )
        return 1
    print(f"perf gate: {compared} ops within {threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
