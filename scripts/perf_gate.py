#!/usr/bin/env python3
"""Perf gate over BENCH_*.json telemetry.

Compares the current benchmark report against a baseline from the
previous CI run and fails (exit 1) when any matching op regresses by
more than the threshold. Rows are matched on their identity keys
(op, n, r, threads, batch, shards, backend); the measured value is
ns_per_op or ns_per_query. The backend key separates SIMD-dispatched
rows from their forced-scalar baselines (gemm_scalar/syrk_scalar), so a
runner whose detected backend changes compares against the right
history instead of tripping a false regression. Skips the comparison gracefully (exit 0) when the
baseline is missing or unreadable — the first run on a fresh repository
has no history.

`--require op1,op2` additionally fails when the *current* report is
missing every row for a listed op — this gates on the presence of the
tracked rows (e.g. the gemm/syrk/par_gemm BLAS-3 rows) even before any
baseline exists, so a refactor cannot silently drop them from the
telemetry.

`--trend PATH` appends the current report's rows to a JSONL trend file
(one object per run: {"run", "sha", "rows": {label: ns}}) carried as a
CI artifact across runs, and renders a multi-run delta table over the
trailing window so drift that stays under the single-run threshold is
still visible in the job summary.

Per-row deltas are printed to stdout and, when running under GitHub
Actions (GITHUB_STEP_SUMMARY set), also written to the job summary as a
markdown table.

Usage: perf_gate.py BASELINE.json CURRENT.json [--threshold 0.25]
                    [--require op1,op2,...] [--trend BENCH_trend.jsonl]
"""

import json
import os
import sys

KEY_FIELDS = ("op", "n", "r", "threads", "batch", "shards", "backend", "level")
VALUE_FIELDS = ("ns_per_op", "ns_per_query")

# Trailing runs shown in the trend table (the JSONL file itself keeps
# the full history).
TREND_WINDOW = 5


def label_of(key):
    return " ".join(f"{k}={v}" for k, v in zip(KEY_FIELDS, key) if v is not None)


def load_rows(path):
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    rows = {}
    for row in doc.get("rows", []):
        key = tuple(row.get(k) for k in KEY_FIELDS)
        value = next(
            (row[v] for v in VALUE_FIELDS if isinstance(row.get(v), (int, float))),
            None,
        )
        if value is not None and value > 0:
            rows[key] = value
    return rows


def write_step_summary(lines):
    """Append markdown lines to the GitHub Actions job summary, if any."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    try:
        with open(path, "a", encoding="utf-8") as fh:
            fh.write("\n".join(lines) + "\n")
    except OSError as exc:  # summary is best-effort, never a gate failure
        print(f"perf gate: could not write step summary ({exc})")


def update_trend(path, current):
    """Append this run's rows to the JSONL trend file; render the
    trailing-window table into the step summary."""
    history = []
    try:
        with open(path, encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    history.append(json.loads(line))
                except ValueError:
                    pass  # tolerate a torn line from an interrupted run
    except OSError:
        pass  # first run: no trend file yet
    entry = {
        "run": len(history) + 1,
        "sha": os.environ.get("GITHUB_SHA", "local")[:12],
        "rows": {label_of(key): value for key, value in current.items()},
    }
    history.append(entry)
    try:
        with open(path, "a", encoding="utf-8") as fh:
            fh.write(json.dumps(entry) + "\n")
    except OSError as exc:  # trend is telemetry, never a gate failure
        print(f"perf gate: could not append trend to {path} ({exc})")
        return
    window = history[-TREND_WINDOW:]
    print(f"perf gate: trend now spans {len(history)} runs ({path})")
    if len(window) < 2:
        return
    header = " | ".join(e["sha"] for e in window)
    lines = [
        f"### Perf trend: last {len(window)} runs (ns)",
        "",
        f"| row | {header} | drift |",
        "| --- |" + " ---: |" * len(window) + " ---: |",
    ]
    for label in sorted(window[-1]["rows"]):
        cells = []
        for e in window:
            v = e["rows"].get(label)
            cells.append(f"{v:.0f}" if isinstance(v, (int, float)) else "—")
        first = next(
            (e["rows"][label] for e in window if isinstance(e["rows"].get(label), (int, float))),
            None,
        )
        last = window[-1]["rows"][label]
        drift = f"{last / first - 1.0:+.1%}" if first else "—"
        lines.append(f"| `{label}` | {' | '.join(cells)} | {drift} |")
    write_step_summary(lines)


def main(argv):
    args = []
    threshold = 0.25
    required = []
    trend_path = None
    it = iter(argv)
    for a in it:
        if a == "--threshold":
            threshold = float(next(it, "0.25"))
        elif a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
        elif a == "--require":
            required = [op for op in next(it, "").split(",") if op]
        elif a.startswith("--require="):
            required = [op for op in a.split("=", 1)[1].split(",") if op]
        elif a == "--trend":
            trend_path = next(it, None)
        elif a.startswith("--trend="):
            trend_path = a.split("=", 1)[1]
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__)
        return 2
    baseline_path, current_path = args

    try:
        current = load_rows(current_path)
    except (OSError, ValueError) as exc:
        print(f"perf gate: current report unreadable ({exc})")
        return 1

    # Presence gate: runs against the current report alone, so it holds
    # even on a fresh repository with no baseline artifact yet.
    present_ops = {key[0] for key in current}
    missing = [op for op in required if op not in present_ops]
    if missing:
        print(
            f"perf gate: current report is missing required op rows: "
            f"{', '.join(missing)} (have: {', '.join(sorted(present_ops))})"
        )
        return 1
    if required:
        print(f"perf gate: required ops present: {', '.join(required)}")

    if trend_path:
        update_trend(trend_path, current)

    try:
        baseline = load_rows(baseline_path)
    except (OSError, ValueError) as exc:
        print(f"perf gate: no usable baseline ({exc}); skipping comparison")
        return 0
    if not baseline:
        print("perf gate: baseline has no comparable rows; skipping comparison")
        return 0

    failures = []
    compared = 0
    summary = [
        "### Perf gate: per-row deltas",
        "",
        "| row | baseline (ns) | current (ns) | delta | status |",
        "| --- | ---: | ---: | ---: | :---: |",
    ]
    for key, base in sorted(baseline.items(), key=str):
        cur = current.get(key)
        if cur is None:
            continue  # op removed or renamed: not a regression
        compared += 1
        ratio = cur / base
        label = label_of(key)
        status = "FAIL" if ratio > 1.0 + threshold else "ok"
        print(f"  [{status}] {label}: {base:.0f} -> {cur:.0f} ns ({ratio - 1.0:+.1%})")
        summary.append(
            f"| `{label}` | {base:.0f} | {cur:.0f} | {ratio - 1.0:+.1%} | {status} |"
        )
        if ratio > 1.0 + threshold:
            failures.append(label)

    if compared == 0:
        print("perf gate: no overlapping rows between baseline and current; skipping")
        return 0
    if failures:
        verdict = (
            f"perf gate: {len(failures)}/{compared} ops regressed "
            f">{threshold:.0%}: {', '.join(failures)}"
        )
    else:
        verdict = f"perf gate: {compared} ops within {threshold:.0%} of baseline"
    summary += ["", verdict]
    write_step_summary(summary)
    print(verdict)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
