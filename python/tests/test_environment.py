"""Dependency-free sanity tests.

These run on any interpreter, so the suite always collects at least one
test even when ``jax`` is absent and every jax-dependent module is
skipped (pytest treats an empty collection as an error — exit code 5 —
which would wrongly fail CI on a jax-less runner).
"""

import importlib.util
from pathlib import Path

PKG = Path(__file__).resolve().parents[1] / "compile"


def test_package_layout():
    assert (PKG / "__init__.py").is_file()
    assert (PKG / "aot.py").is_file()
    assert (PKG / "model.py").is_file()
    assert (PKG / "kernels" / "pairwise.py").is_file()
    assert (PKG / "kernels" / "ref.py").is_file()


def test_jax_availability_is_reported():
    # Informational: the jax-dependent modules skip themselves via
    # conftest.py when this is None. Either state is valid.
    spec = importlib.util.find_spec("jax")
    assert spec is None or spec.name == "jax"
