"""L2 graph correctness: model.py functions against numpy references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.0, 1.0, shape), jnp.float32)


def test_kernel_block_symmetric_unit_diag():
    x = rand((32, 4), 1)
    k = model.kernel_block_symmetric("gaussian", x, jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(jnp.diag(k)), 1.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k).T, atol=1e-6)


def test_rff_features_approximate_gaussian():
    rng = np.random.default_rng(2)
    sigma, d, r = 0.9, 3, 8192
    x = rand((6, d), 3)
    omega = jnp.asarray(rng.normal(0, 1.0 / sigma, (r, d)), jnp.float32)
    b = jnp.asarray(rng.uniform(0, 2 * np.pi, r), jnp.float32)
    phi = model.rff_features(x, omega, b)
    approx = np.asarray(phi) @ np.asarray(phi).T
    want = np.asarray(ref.gaussian(x, x, sigma))
    np.testing.assert_allclose(approx, want, atol=0.08)


def test_krr_solve_matches_numpy():
    n = 32
    x = rand((n, 3), 4)
    k = np.asarray(ref.gaussian(x, x, 0.6), np.float64)
    y = np.asarray(rand((n, 1), 5), np.float64)
    lam = 0.1
    got = model.krr_solve(jnp.asarray(k, jnp.float32),
                          jnp.asarray(y, jnp.float32), jnp.float32(lam))
    want = np.linalg.solve(k + lam * np.eye(n), y)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-4)


def test_nystrom_features_gram_matches_nystrom_kernel():
    x = rand((32, 4), 6)
    lm = rand((16, 4), 7)
    sigma = 0.7
    phi = model.nystrom_features(x, lm, jnp.float32(sigma))
    gram = np.asarray(phi) @ np.asarray(phi).T
    kxl = np.asarray(ref.gaussian(x, lm, sigma), np.float64)
    kll = np.asarray(ref.gaussian(lm, lm, sigma), np.float64)
    kll[np.diag_indices(16)] = 1.0
    want = kxl @ np.linalg.solve(kll + 1e-6 * np.eye(16), kxl.T)
    np.testing.assert_allclose(gram, want, rtol=5e-3, atol=5e-4)


def test_graphs_are_jittable():
    x = rand((32, 8), 8)
    f = jax.jit(lambda a, s: model.kernel_block("gaussian", a, a, s))
    k1 = f(x, jnp.float32(0.5))
    k2 = f(x, jnp.float32(1.5))  # same trace, new sigma
    assert k1.shape == (32, 32)
    assert not np.allclose(np.asarray(k1), np.asarray(k2))
