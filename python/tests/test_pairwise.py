"""L1 correctness: the Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes, tile sizes, bandwidths and families; explicit
cases pin the edge geometry (single tile, many tiles, d=1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property sweeps below need hypothesis; skip this module (not the whole
# suite) when it is not installed.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pairwise import (
    FAMILIES,
    default_block,
    pairwise_block,
    vmem_bytes,
)


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.0, 1.0, shape), jnp.float32)


def assert_matches_ref(family, m, n, d, sigma, bm, bn, seed=0):
    x = rand((m, d), seed)
    y = rand((n, d), seed + 1)
    got = pairwise_block(x, y, jnp.float32(sigma), family=family, bm=bm, bn=bn)
    want = ref.block(family, x, y, sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("family", FAMILIES)
def test_single_tile(family):
    b = default_block(family)
    assert_matches_ref(family, b, b, 8, 0.7, b, b)


@pytest.mark.parametrize("family", FAMILIES)
def test_multi_tile_grid(family):
    bm, bn = 16, 8
    assert_matches_ref(family, 48, 24, 5, 1.3, bm, bn, seed=3)


@pytest.mark.parametrize("family", FAMILIES)
def test_d_equals_one(family):
    assert_matches_ref(family, 8, 8, 1, 0.4, 8, 8, seed=5)


def test_unit_diagonal_on_identical_points():
    x = rand((16, 4), 7)
    for family in FAMILIES:
        k = pairwise_block(x, x, jnp.float32(0.9), family=family, bm=16, bn=16)
        np.testing.assert_allclose(np.asarray(jnp.diag(k)), 1.0, rtol=1e-5)


def test_zero_padding_is_exact():
    """Padding the feature dim with zeros must not change the result —
    the property the Rust runtime's d-bucketing relies on."""
    x, y = rand((16, 5), 9), rand((16, 5), 10)
    xp = jnp.pad(x, ((0, 0), (0, 3)))
    yp = jnp.pad(y, ((0, 0), (0, 3)))
    for family in FAMILIES:
        a = pairwise_block(x, y, jnp.float32(0.6), family=family, bm=16, bn=16)
        b = pairwise_block(xp, yp, jnp.float32(0.6), family=family, bm=16, bn=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_sigma_is_runtime_input():
    """One jitted instance must serve multiple bandwidths (no retrace:
    sigma is traced, family/tiles are static)."""
    x, y = rand((16, 4), 11), rand((16, 4), 12)
    for sigma in (0.1, 0.5, 2.0):
        got = pairwise_block(x, y, jnp.float32(sigma), family="gaussian",
                             bm=16, bn=16)
        want = ref.gaussian(x, y, sigma)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)


def test_rejects_bad_shapes():
    x = rand((10, 3), 1)
    with pytest.raises(ValueError):
        pairwise_block(x, x, jnp.float32(1.0), family="gaussian", bm=8, bn=8)
    with pytest.raises(ValueError):
        pairwise_block(x, rand((10, 4), 2), jnp.float32(1.0),
                       family="gaussian", bm=10, bn=10)
    with pytest.raises(ValueError):
        pairwise_block(x, x, jnp.float32(1.0), family="cauchy", bm=10, bn=10)


@settings(max_examples=25, deadline=None)
@given(
    family=st.sampled_from(FAMILIES),
    tiles_m=st.integers(1, 3),
    tiles_n=st.integers(1, 3),
    bm=st.sampled_from([4, 8, 16]),
    d=st.integers(1, 12),
    sigma=st.floats(0.05, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes_and_bandwidths(family, tiles_m, tiles_n, bm, d,
                                          sigma, seed):
    m, n = tiles_m * bm, tiles_n * bm
    assert_matches_ref(family, m, n, d, sigma, bm, bm, seed=seed)


def test_vmem_estimate_fits_tpu_budget():
    """DESIGN.md §8: the default tiles must fit comfortably in 16 MiB VMEM
    with room for double buffering."""
    for family in FAMILIES:
        b = default_block(family)
        for d in (8, 32, 64, 128):
            bytes_per_step = vmem_bytes(family, b, b, d)
            assert bytes_per_step * 2 < 16 * 2**20, (family, d, bytes_per_step)


def test_values_in_unit_interval():
    x, y = rand((32, 6), 20), rand((32, 6), 21)
    for family in FAMILIES:
        k = np.asarray(pairwise_block(x, y, jnp.float32(0.8), family=family,
                                      bm=16, bn=16))
        assert (k > 0.0).all() and (k <= 1.0 + 1e-6).all()


def test_f64_inputs_are_downcast():
    x = jnp.asarray(np.random.default_rng(0).uniform(size=(8, 3)))
    k = pairwise_block(x, x, jnp.float32(1.0), family="gaussian", bm=8, bn=8)
    assert k.dtype == jnp.float32
