"""AOT pipeline: artifacts lower to parseable HLO text + sane manifest."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from compile import aot


def test_to_hlo_text_smoke():
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda a, b: (jnp.dot(a, b) + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_artifact_specs_cover_families_and_buckets():
    names = [name for name, _, _ in aot.artifact_specs()]
    for family in ("gaussian", "laplace", "imq"):
        for d in aot.D_BUCKETS:
            assert any(f"kb_{family}_d{d}_" in n for n in names), (family, d)
    assert any(n.startswith("rff_") for n in names)
    assert any(n.startswith("krr_solve_") for n in names)


@pytest.mark.slow
def test_full_emission(tmp_path):
    """End-to-end: run the module CLI into a temp dir, validate outputs."""
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["hlo"] == "text"
    assert len(manifest["artifacts"]) >= 3 * len(aot.D_BUCKETS) + 2
    for entry in manifest["artifacts"]:
        text = (out / entry["file"]).read_text()
        assert text.startswith("HloModule"), entry["name"]
        assert "ROOT" in text, entry["name"]
