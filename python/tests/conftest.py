"""Shared pytest configuration for the L1/L2 test suite.

Every test module in this directory exercises JAX (the Pallas kernel, the
AOT lowering, the L2 graphs). CI runners and minimal dev machines may not
have ``jax`` installed; in that case the whole suite must *skip*, not
fail — the Rust tier-1 suite is independent of it. ``test_pairwise``
additionally needs ``hypothesis`` and skips on its own when that is
missing (see its ``importorskip``).
"""

import importlib.util

#: Modules that import jax at module scope and cannot even be collected
#: without it. test_environment.py stays runnable everywhere.
JAX_DEPENDENT = ["test_aot.py", "test_model.py", "test_pairwise.py"]

collect_ignore: list[str] = []

if importlib.util.find_spec("jax") is None:
    # Without jax these modules fail at import time; skip their
    # collection entirely rather than erroring out.
    collect_ignore.extend(JAX_DEPENDENT)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end cases (full AOT emission)"
    )
