"""Pure-jnp oracle for the Pallas pairwise kernel — the correctness
reference every L1 test asserts against (and the numerics the Rust
`kernels::compute` module mirrors in f64)."""

from __future__ import annotations

import jax.numpy as jnp


def sqdist(x, y):
    """Pairwise squared Euclidean distances, numerically direct."""
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def l1dist(x, y):
    """Pairwise L1 (Manhattan) distances."""
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def gaussian(x, y, sigma):
    """exp(−|x−y|² / (2σ²)) — paper eq. (5)."""
    return jnp.exp(-sqdist(x, y) / (2.0 * sigma * sigma))


def laplace(x, y, sigma):
    """exp(−|x−y|₁ / σ) — paper §5.4."""
    return jnp.exp(-l1dist(x, y) / sigma)


def imq(x, y, sigma):
    """σ/√(|x−y|² + σ²) — inverse multiquadric, normalized to 1 at 0."""
    return sigma / jnp.sqrt(sqdist(x, y) + sigma * sigma)


def block(family: str, x, y, sigma):
    """Dispatch by family name."""
    fn = {"gaussian": gaussian, "laplace": laplace, "imq": imq}[family]
    return fn(x, y, sigma)
