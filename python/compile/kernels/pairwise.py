"""Layer 1 — Pallas kernel for tiled pairwise-distance kernel blocks.

This is the compute hot spot of the whole system: every factor of the
hierarchical kernel (leaf blocks A_ii, landmark Grams Sigma_p, the
cross blocks behind U_i and W_p), the Nystrom features and the exact
baseline all reduce to evaluating K(X, Y) for point blocks.

TPU design (see DESIGN.md §8 — Hardware adaptation):

- The grid tiles the (m, n) output into ``bm x bn`` blocks (default
  128 x 128, the MXU-native shape). Each step stages one X tile
  (bm x d) and one Y tile (bn x d) from HBM into VMEM via BlockSpec —
  the same HBM<->VMEM schedule a CUDA version would express with
  threadblocks + shared memory.
- For squared-L2 kernels (gaussian, imq) the distance uses the
  ``|x − y|² = |x|² + |y|² − 2 x·yᵀ`` expansion: the −2xyᵀ term is a
  (bm x d)·(d x bn) contraction on the MXU with f32 accumulation
  (``preferred_element_type``); the norms are cheap VPU reductions.
- The Laplace kernel needs an L1 distance, which has no matmul form;
  it broadcasts to (bm, bn, d) inside VMEM, so its tiles default to
  32 x 32 to bound the footprint.
- ``sigma`` is a runtime (1, 1) input — one compiled artifact serves
  every bandwidth in a grid search.

On this CPU testbed the kernel MUST run with ``interpret=True``:
real-TPU lowering emits a Mosaic custom-call that the CPU PJRT plugin
cannot execute. Numerics are identical; pytest checks them against the
pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FAMILIES = ("gaussian", "laplace", "imq")

#: MXU-friendly default tile for squared-L2 kernels.
DEFAULT_BLOCK = 128
#: Smaller tile for the broadcast-heavy L1 (Laplace) path.
LAPLACE_BLOCK = 32


def _kernel_body(family: str, sig_ref, x_ref, y_ref, o_ref):
    """One grid step: compute the (bm, bn) output tile."""
    x = x_ref[...]  # (bm, d) in VMEM
    y = y_ref[...]  # (bn, d) in VMEM
    sigma = sig_ref[0, 0]
    if family == "laplace":
        # L1 distance: broadcast-subtract inside VMEM.
        dist = jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
        o_ref[...] = jnp.exp(-dist / sigma)
        return
    # Squared-L2 via the gemm expansion; the dot hits the MXU.
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    yn = jnp.sum(y * y, axis=1, keepdims=True)  # (bn, 1)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(xn + yn.T - 2.0 * xy, 0.0)  # guard cancellation
    if family == "gaussian":
        o_ref[...] = jnp.exp(-d2 / (2.0 * sigma * sigma))
    elif family == "imq":
        o_ref[...] = sigma * jax.lax.rsqrt(d2 + sigma * sigma)
    else:  # pragma: no cover - guarded by FAMILIES
        raise ValueError(f"unknown family {family!r}")


def default_block(family: str) -> int:
    """Default tile edge for a kernel family."""
    return LAPLACE_BLOCK if family == "laplace" else DEFAULT_BLOCK


@functools.partial(
    jax.jit, static_argnames=("family", "bm", "bn", "interpret")
)
def pairwise_block(x, y, sigma, *, family: str, bm: int | None = None,
                   bn: int | None = None, interpret: bool = True):
    """K(X, Y) for f32 blocks, tiled ``bm x bn``.

    ``x``: (m, d), ``y``: (n, d) with m % bm == 0 and n % bn == 0 (the
    AOT pipeline emits fixed padded shapes; the Rust runtime pads).
    ``sigma``: scalar bandwidth (traced — not baked into the artifact).
    """
    if family not in FAMILIES:
        raise ValueError(f"family must be one of {FAMILIES}, got {family!r}")
    m, d = x.shape
    n, d2 = y.shape
    if d != d2:
        raise ValueError(f"dim mismatch: {d} vs {d2}")
    bm = bm or min(default_block(family), m)
    bn = bn or min(default_block(family), n)
    if m % bm or n % bn:
        raise ValueError(f"shape ({m},{n}) not divisible by tile ({bm},{bn})")
    sig = jnp.asarray(sigma, jnp.float32).reshape(1, 1)
    body = functools.partial(_kernel_body, family)
    return pl.pallas_call(
        body,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(sig, x.astype(jnp.float32), y.astype(jnp.float32))


def vmem_bytes(family: str, bm: int, bn: int, d: int) -> int:
    """Analytic VMEM footprint of one grid step (f32), for DESIGN.md §8."""
    base = 4 * (bm * d + bn * d + bm * bn) + 4
    if family == "laplace":
        base += 4 * bm * bn * d  # broadcast intermediate
    return base
