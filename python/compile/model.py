"""Layer 2 — JAX compute graphs, AOT-lowered once to HLO text.

The hierarchical kernel's coordination logic lives in Rust (Layer 3);
what XLA accelerates are the dense numeric kernels: pairwise kernel
blocks (which call the Layer-1 Pallas kernel so both layers lower into
one HLO module), random-Fourier feature maps, and a ridge solve. Every
function here is pure, fixed-shape, and f32 — exactly what
``jax.jit(...).lower(...)`` needs for ahead-of-time compilation (see
``aot.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.pairwise import pairwise_block


def kernel_block(family: str, x, y, sigma):
    """K(X, Y) through the Pallas kernel (L1 inside L2)."""
    return pairwise_block(x, y, sigma, family=family)


def kernel_block_symmetric(family: str, x, sigma):
    """K(X, X) with exact unit diagonal (kernel value at zero distance)."""
    k = pairwise_block(x, x, sigma, family=family)
    n = x.shape[0]
    eye = jnp.eye(n, dtype=k.dtype)
    return k * (1.0 - eye) + eye


def rff_features(x, omega, b):
    """Random Fourier features φ(x) = √(2/r) cos(x ωᵀ + b) — eq. (7)."""
    r = omega.shape[0]
    proj = jnp.dot(x, omega.T, preferred_element_type=jnp.float32)
    return jnp.sqrt(2.0 / r) * jnp.cos(proj + b[None, :])


def krr_solve(k, y, lam):
    """Dense ridge solve (K + λI)^{-1} y (Cholesky inside XLA)."""
    n = k.shape[0]
    a = k + lam * jnp.eye(n, dtype=k.dtype)
    cf = jax.scipy.linalg.cho_factor(a, lower=True)
    return jax.scipy.linalg.cho_solve(cf, y)


def nystrom_features(x, landmarks, sigma, family: str = "gaussian"):
    """φ(x) = L^{-1} k(X̲, x) with K(X̲, X̲) = L Lᵀ (eq. 6's feature map)."""
    kll = kernel_block_symmetric(family, landmarks, sigma)
    kxl = kernel_block(family, x, landmarks, sigma)
    l = jnp.linalg.cholesky(kll + 1e-6 * jnp.eye(kll.shape[0], dtype=kll.dtype))
    # φ rows solve L φᵀ = k(X̲, x).
    return jax.scipy.linalg.solve_triangular(l, kxl.T, lower=True).T
