"""AOT lowering: JAX (L2, embedding the L1 Pallas kernel) → HLO text.

Emits one ``.hlo.txt`` per (operation, shape bucket) plus a
``manifest.json`` the Rust runtime reads to discover artifacts. HLO
*text* is the interchange format — NOT a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Shape buckets: the Rust side pads a kernel-block request up to the
nearest bucket (tile rows to 128 — 32 for Laplace — and the feature
dimension to the nearest of D_BUCKETS); padding coordinates with zeros
on *both* sides adds exactly zero distance, so the padded result is
exact for every supported kernel.

Usage: ``python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.pairwise import FAMILIES, default_block

#: Feature-dimension buckets the kernel-block artifacts are emitted for.
D_BUCKETS = (8, 32, 64, 128)
#: RFF artifact shape: (tile of points, frequencies).
RFF_SHAPE = (128, 32, 256)
#: Dense ridge-solve artifact order.
KRR_N = 256


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """Yield (name, lowered, meta) for every artifact."""
    for family in FAMILIES:
        tile = default_block(family)
        for d in D_BUCKETS:
            name = f"kb_{family}_d{d}_{tile}x{tile}"

            def fn(x, y, sigma, family=family):
                return (model.kernel_block(family, x, y, sigma),)

            lowered = jax.jit(fn).lower(f32(tile, d), f32(tile, d), f32())
            meta = {
                "op": "kernel_block",
                "family": family,
                "tile_m": tile,
                "tile_n": tile,
                "d": d,
                "inputs": [[tile, d], [tile, d], []],
                "outputs": [[tile, tile]],
            }
            yield name, lowered, meta

    m, d, r = RFF_SHAPE

    def rff_fn(x, omega, b):
        return (model.rff_features(x, omega, b),)

    lowered = jax.jit(rff_fn).lower(f32(m, d), f32(r, d), f32(r))
    yield f"rff_d{d}_r{r}", lowered, {
        "op": "rff_features",
        "family": "gaussian",
        "tile_m": m,
        "d": d,
        "r": r,
        "inputs": [[m, d], [r, d], [r]],
        "outputs": [[m, r]],
    }

    def solve_fn(k, y, lam):
        return (model.krr_solve(k, y, lam),)

    lowered = jax.jit(solve_fn).lower(f32(KRR_N, KRR_N), f32(KRR_N, 1), f32())
    yield f"krr_solve_n{KRR_N}", lowered, {
        "op": "krr_solve",
        "n": KRR_N,
        "inputs": [[KRR_N, KRR_N], [KRR_N, 1], []],
        "outputs": [[KRR_N, 1]],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": 1, "hlo": "text", "artifacts": []}
    for name, lowered, meta in artifact_specs():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as fh:
            fh.write(text)
        entry = {"name": name, "file": fname, **meta}
        manifest["artifacts"].append(entry)
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
