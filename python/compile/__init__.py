"""The Python build-time side of the three-layer system.

- ``compile.kernels`` — L1: Pallas kernels for the pairwise hot spot.
- ``compile.model`` — L2: JAX compute graphs embedding the L1 kernels.
- ``compile.aot`` — the AOT lowering CLI emitting HLO-text artifacts +
  manifest for the Rust (L3) PJRT runtime.

A regular package (not a PEP-420 namespace) so ``python -m compile.aot``
and ``from compile import model`` resolve identically from the
``python/`` directory regardless of interpreter/pytest path handling.
Python only runs at build time; inference is pure Rust.
"""
