"""Root conftest for the ``python/`` tree.

Present (and intentionally empty of hooks) so pytest inserts this
directory onto ``sys.path`` during collection, making ``import
compile`` resolve under the bare ``pytest`` binary as well as
``python -m pytest``. Test-suite configuration (jax gating, markers)
lives in ``tests/conftest.py``.
"""
